"""End-to-end serving driver — batched requests against a compressed model.

The paper is an inference paper, so the e2e driver is a serving loop:
a request pool with mixed prompt lengths is padded into batches, prefilled
once, then decoded step-by-step from the compressed weights, reporting
tokens/s and per-phase latency (the paper's latency columns, batched).

    PYTHONPATH=src python examples/serve_batched.py [--requests 16]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import CompressionPolicy
from repro.models import lm as LM
from repro.serve.engine import build_serve_params, make_serve_fns
from repro.train.data import DataConfig, DataPipeline


def build_requests(data, n, min_len=8, max_len=24, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        ln = int(rng.integers(min_len, max_len + 1))
        toks = np.asarray(data.batch_at(2000 + i)["tokens"])[0, :ln]
        reqs.append(toks)
    return reqs


def pad_batch(reqs, pad_id=0):
    """Left-pad to a rectangle (decode positions align on the right)."""
    ln = max(len(r) for r in reqs)
    out = np.full((len(reqs), ln), pad_id, np.int32)
    for i, r in enumerate(reqs):
        out[i, ln - len(r):] = r
    return jnp.asarray(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mode", default="compressed",
                    choices=["dense", "quant", "compressed"])
    args = ap.parse_args()

    cfg = get_config("llama3.2-1b").smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size, batch=4,
                                   seq_len=32))

    if args.mode == "dense":
        serve_params, lut = params, None
    else:
        st = build_serve_params(params, CompressionPolicy(
            mode=args.mode, min_weight_size=1024))
        serve_params, lut = st.params, st.lut
        print(f"weights: {args.mode}, "
              f"{sum(st.stats.values())/2**20:.2f} MiB on device")

    reqs = build_requests(data, args.requests)
    batch = pad_batch(reqs)
    b, t0 = batch.shape
    max_len = t0 + args.max_new

    # jitted + cached per config — repeated calls reuse the same executable
    prefill, decode_step = make_serve_fns(cfg)

    caches = LM.init_caches(cfg, b, max_len, dtype=jnp.float32)
    t_start = time.perf_counter()
    logits, caches = prefill(serve_params, lut, {"tokens": batch}, caches)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t_start

    tok = jnp.argmax(logits, axis=-1)[:, None].astype(batch.dtype)
    outs = [tok]
    t_start = time.perf_counter()
    for i in range(args.max_new - 1):
        logits, caches = decode_step(serve_params, lut, tok, caches, t0 + i)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(batch.dtype)
        outs.append(tok)
    tok.block_until_ready()
    t_decode = time.perf_counter() - t_start

    gen = np.concatenate([np.asarray(o) for o in outs], axis=1)
    n_tokens = b * args.max_new
    print(f"served {args.requests} requests (batch={b}, prompt<= {t0}): "
          f"prefill {t_prefill*1e3:.1f} ms, "
          f"decode {t_decode*1e3:.1f} ms ({n_tokens/max(t_decode,1e-9):.1f} "
          "tok/s incl. per-step decompression)")
    print("first request continuation:", gen[0].tolist())


if __name__ == "__main__":
    main()
