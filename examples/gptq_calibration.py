"""GPTQ calibration example — data-dependent quantization (paper §3).

Quantizes one trained layer three ways (naive per-tensor like the paper's
Listing 1, naive per-channel, GPTQ with real calibration activations) and
reports the task-loss degradation of each, reproducing the paper's reason
for adopting GPTQ.

    PYTHONPATH=src python examples/gptq_calibration.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import gptq
from repro.core.quant import QuantConfig, quantize, dequantize
from repro.models import lm as LM
from repro.train.data import DataConfig, DataPipeline
from repro.train.optimizer import AdamWConfig
from repro.train.steps import (TrainConfig, make_train_step,
                               init_train_state, cross_entropy)


def main():
    cfg = get_config("llama3.2-1b").smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size, batch=16,
                                   seq_len=32))
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-2, warmup_steps=10,
                                             total_steps=150))
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    for i in range(120):
        state, _ = step(state, data.batch_at(i))
    params = state["params"]

    batch = data.batch_at(9000)

    @jax.jit
    def eval_loss(p):
        logits, _, _ = LM.forward(p, cfg, batch["tokens"])
        return cross_entropy(logits, batch["labels"])

    base = float(eval_loss(params))
    print(f"fp32 loss: {base:.4f}")

    # Calibration: capture the real input activations of every mlp.w_gate
    # by running the embed+attn prefix — here we approximate with the
    # residual-stream statistics (hidden states after the embed).
    toks = data.batch_at(500)["tokens"]
    hidden, _, _ = LM.forward(params, cfg, toks, return_hidden=True)
    calib = hidden.reshape(-1, cfg.d_model)

    bits = 4
    for scheme in ("naive-per-tensor", "naive-per-channel", "gptq"):
        def q_one(path, p):
            name = jax.tree_util.keystr(path)
            if p.ndim != 2 or p.size < 1024 or "norm" in name:
                return p
            if scheme == "naive-per-tensor":
                return dequantize(quantize(p, QuantConfig(
                    bits=bits, granularity="per_tensor")))
            if scheme == "naive-per-channel":
                return dequantize(quantize(p, QuantConfig(
                    bits=bits, granularity="per_channel")))
            if p.shape[1] != cfg.d_model:
                return dequantize(quantize(p, QuantConfig(
                    bits=bits, granularity="per_channel")))
            h = gptq.accumulate_hessian(gptq.init_hessian(p.shape[1]), calib)
            return dequantize(gptq.gptq_quantize(p, h, QuantConfig(bits=bits)))

        qp = jax.tree_util.tree_map_with_path(q_one, params)
        l = float(eval_loss(qp))
        print(f"{scheme:20s} {bits}-bit loss: {l:.4f}  (delta {l-base:+.4f})")


if __name__ == "__main__":
    main()
