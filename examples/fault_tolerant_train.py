"""Fault-tolerant training driver — checkpoint/restart + elastic restore.

Trains a small LM with the production loop: periodic atomic checkpoints,
simulated preemption mid-run, automatic resume from the last commit, and
an elastic restore onto a different mesh topology at the end.  The same
code path a 1000-node launcher wraps (DESIGN.md §7).

    PYTHONPATH=src python examples/fault_tolerant_train.py [--steps 60]
"""
import argparse
import shutil

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import lm as LM
from repro.sharding import partition as PT
from repro.train.data import DataConfig, DataPipeline
from repro.train.fault import FaultConfig, FaultTolerantLoop, elastic_restore
from repro.train.optimizer import AdamWConfig
from repro.train.steps import TrainConfig, make_train_step, init_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ft_example")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    cfg = get_config("llama3.2-1b").smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size, batch=8,
                                   seq_len=32))
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=5e-3, warmup_steps=10,
                                             total_steps=args.steps))
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    fcfg = FaultConfig(ckpt_dir=args.ckpt_dir, ckpt_every=10, keep=3)

    losses = []
    def on_metrics(s, m):
        losses.append(float(m["loss"]))
        if s % 10 == 0:
            print(f"step {s:4d} loss {losses[-1]:.3f}")

    # Phase 1: run 60% of the way, then "crash" (stop the loop).
    half = (args.steps * 6 // 10 // 10) * 10
    loop = FaultTolerantLoop(step, state, data, fcfg, on_metrics=on_metrics)
    loop.run(half)
    print(f"--- simulated preemption after step {half} ---")

    # Phase 2: a fresh process resumes from the last committed checkpoint.
    loop2 = FaultTolerantLoop(step, init_train_state(params, tcfg), data,
                              fcfg, on_metrics=on_metrics)
    resumed_at = loop2.maybe_resume()
    print(f"resumed from committed step {resumed_at}")
    final_state = loop2.run(args.steps)
    print(f"finished at step {args.steps}, loss {losses[-1]:.3f}")

    # Phase 3: elastic restore onto a (new) mesh — survivor topology.
    mesh = make_host_mesh()
    def make_shardings(like, m):
        return PT.to_named(PT.make_train_state_specs(like, m), m)
    restored, at = elastic_restore(args.ckpt_dir, final_state, mesh,
                                   make_shardings)
    print(f"elastic restore onto mesh {dict(mesh.shape)} at step {at}: ok")
    assert losses[0] > losses[-1], "training should have reduced the loss"


if __name__ == "__main__":
    main()
