"""Quickstart — the whole Tiny-QMoE pipeline in one script.

Builds a small llama3.2-family model, trains it briefly so the weights
have real structure, quantizes + dictionary-compresses it (the paper's
§3+§4 pipeline), and serves greedy generations from the compressed form —
verifying the compressed output is bit-identical to the quantized model.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import CompressionPolicy
from repro.models import lm as LM
from repro.serve.context import ServeContext
from repro.serve.engine import build_serve_params, generate
from repro.train.data import DataConfig, DataPipeline
from repro.train.optimizer import AdamWConfig
from repro.train.steps import TrainConfig, make_train_step, init_train_state


def main():
    # 1. A small model with learned structure (random weights don't compress).
    cfg = get_config("llama3.2-1b").smoke
    print(f"model: {cfg.name}  layers={cfg.n_layers} d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size, batch=16,
                                   seq_len=32, seed=0))
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-2, warmup_steps=10,
                                             total_steps=200))
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    for i in range(100):
        state, m = step(state, data.batch_at(i))
    print(f"trained 100 steps, loss={float(m['loss']):.3f}")
    params = state["params"]

    # 2. Quantize + compress (paper §3 + §4).
    dense_bytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(params))
    st = build_serve_params(params, CompressionPolicy(mode="compressed",
                                                      min_weight_size=1024))
    comp_bytes = sum(st.stats.values())
    print(f"dense {dense_bytes/2**20:.2f} MiB -> compressed "
          f"{comp_bytes/2**20:.2f} MiB "
          f"({dense_bytes/comp_bytes:.1f}x, dictionary={len(st.table or {})} "
          "entries)")

    # 3. Serve from the compressed weights (decompress-on-demand in-graph).
    prompt = jnp.asarray(np.asarray(data.batch_at(999)["tokens"])[:2, :16])
    out_c = generate(st.params, cfg, prompt,
                     ctx=ServeContext.from_state(cfg, st), max_new=12)

    # 4. Losslessness check: compressed == quantized, token for token.
    sq = build_serve_params(params, CompressionPolicy(mode="quant",
                                                      min_weight_size=1024))
    out_q = generate(sq.params, cfg, prompt,
                     ctx=ServeContext.from_state(cfg, sq), max_new=12)
    exact = bool((np.asarray(out_c) == np.asarray(out_q)).all())
    print(f"compressed generation: {np.asarray(out_c)[0, -12:].tolist()}")
    print(f"matches quantized model exactly: {exact}")
    assert exact, "dictionary codec must be lossless over quantized weights"


if __name__ == "__main__":
    main()
