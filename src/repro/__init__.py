"""repro — Tiny-QMoE as a production multi-pod JAX framework.

Layers: core (quant+codec), models (assigned arch zoo), kernels (Pallas),
sharding, serve, train, configs, launch.  See DESIGN.md.
"""
__version__ = "1.0.0"
