"""Pure-jnp oracles for every Pallas kernel.

These define the semantics; kernels must match them to tolerance
(bit-exact for dict_decode, allclose for the float kernels).  On CPU
backends ``ops.py`` dispatches here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.codec import ESCAPE


def _shard_bgrtd(x):
    """(B, G, R, T[, D]) grouped-head layout: batch on data axes; model on
    the kv-group dim when it divides, else on T — keeps flash score chunks
    sharded through the GQA reshape (the reshape otherwise drops head
    sharding and SPMD all-gathers 8 GiB score chunks; §Perf iteration 4).
    Placement matches layers._attend_full: heads-divisible → heads, else
    q-time (context parallel, k/v replicated)."""
    from repro.sharding.partition import _current_axis_sizes, constrain
    axis_sizes, _ = _current_axis_sizes()
    msize = axis_sizes.get("model", 1)
    batch = ("pod", "data")
    if msize <= 1:
        return x
    if x.shape[1] % msize == 0:
        return constrain(x, batch, "model")
    if x.shape[1] * x.shape[2] % msize == 0:
        # (g, r) product split: inexpressible as a PartitionSpec — leave it
        # to SPMD propagation from q's head sharding through the reshape.
        return x
    return constrain(x, batch, None, None, "model")


def dequant_matmul(x: jax.Array, wq: jax.Array, scale: jax.Array,
                   zero: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """y = x @ dequant(wq).T

    x:     (M, K) float
    wq:    (N, K) uint8 codes
    scale: (N, 1) f32, zero: (N, 1) f32  (per-output-channel affine)
    """
    w = (wq.astype(jnp.float32) - zero) * scale          # (N, K)
    y = jnp.dot(x.astype(jnp.float32), w.T,
                preferred_element_type=jnp.float32)
    return y.astype(out_dtype)


def dict_decode(codes: jax.Array, literals: jax.Array, nlit: jax.Array,
                lut: jax.Array) -> jax.Array:
    """Blocked-codec decode: (nb, slots) codes -> (nb, slots*S) uint8.

    Same math as ``repro.core.blocked_codec.decode_blocked_jnp`` but kept
    here in kernel-matching layout (per-block rows, no flatten/trim).
    """
    c = codes.astype(jnp.int32)
    is_esc = c == ESCAPE
    safe = jnp.where(is_esc, 0, c)
    from_dict = lut[safe]                                   # (nb, slots, S)
    rank = jnp.clip(jnp.cumsum(is_esc.astype(jnp.int32), axis=1) - 1,
                    0, literals.shape[1] - 1)
    from_lit = jax.vmap(lambda lit, r: lit[r])(literals, rank)  # (nb, slots, S)
    out = jnp.where(is_esc[:, :, None], from_lit, from_dict)
    return out.reshape(codes.shape[0], -1)


def dict_decode_dequant_matmul(x, codes, literals, nlit, lut, scale, zero,
                               n, k, out_dtype=jnp.float32):
    """Fused reference: decode -> (N, K) codes -> dequant matmul."""
    wq = dict_decode(codes, literals, nlit, lut).reshape(-1)[: n * k]
    return dequant_matmul(x, wq.reshape(n, k), scale, zero, out_dtype)


def tiled_decode_weight(codes, literals, nlit, lut, shape,
                        tile_n: int, tile_k: int) -> jax.Array:
    """Decode tile-major planes (blocked_codec.encode_blocked_tiled layout)
    back to the dense (N, K) uint8 weight."""
    n, k = shape
    flat = dict_decode(codes, literals, nlit, lut).reshape(
        n // tile_n, k // tile_k, tile_n, tile_k)
    return jnp.moveaxis(flat, -3, -2).reshape(n, k)


def fused_decode_matmul(x, codes, literals, nlit, lut, scale, zero, *,
                        shape, tile_n: int, tile_k: int,
                        out_dtype=jnp.float32) -> jax.Array:
    """Oracle for the fused decode→dequant→matmul megakernel.

    Same semantics as decode + :func:`dequant_matmul`, but structured the
    way the Pallas kernel executes: walk K in ``tile_k`` strips, decode only
    that strip's blocks, accumulate ``x_k @ q_k.T`` plus a running row-sum
    of x, and apply the per-channel affine once in the epilogue

        y = s · (Σ_k x_k·q_k − z·Σ x)

    so the dense weight (and its dequantized f32 view) is never
    materialized — peak working set is one decoded (N, tile_k) strip.
    Strip counts in practice are small (K/tile_k ≤ a few dozen), so the
    strip loop is unrolled into the trace — ``lax.scan`` loop machinery
    alone costs enough on CPU to erase the fusion win at 1024²
    (measured: unrolled 1.04x/1.57x vs unfused at 1024²/4096², scan
    0.84x/1.50x); scan remains the fallback for very deep K.

    ``codes``/``literals``/``nlit`` are in the tile-major layout of
    ``blocked_codec.encode_blocked_tiled`` (tiles row-major over the
    (N/tile_n, K/tile_k) grid, each tile a contiguous block range).
    """
    n, k = shape
    m = x.shape[0]
    nnt, nkt = n // tile_n, k // tile_k
    nb, slots = codes.shape
    bpt = nb // (nnt * nkt)
    cap, s = literals.shape[1], literals.shape[2]

    # Regroup tile-major (j-outer, k-inner) block rows into K-strips:
    # strip k holds the blocks of tiles (0..nnt-1, k), i.e. the full
    # (N, tile_k) weight column band.
    codes_s = codes.reshape(nnt, nkt, bpt, slots).transpose(1, 0, 2, 3)
    lits_s = literals.reshape(nnt, nkt, bpt, cap, s).transpose(1, 0, 2, 3, 4)
    nlit_s = nlit.reshape(nnt, nkt, bpt).transpose(1, 0, 2)
    x_s = x.astype(jnp.float32).reshape(m, nkt, tile_k).transpose(1, 0, 2)

    def strip_dot(acc, cs, ls, ns, xk):
        q = dict_decode(cs.reshape(-1, slots), ls.reshape(-1, cap, s),
                        ns.reshape(-1), lut).reshape(n, tile_k)
        return acc + jnp.dot(xk, q.astype(jnp.float32).T,
                             preferred_element_type=jnp.float32)

    acc = jnp.zeros((m, n), jnp.float32)
    if nkt <= 64:
        for ki in range(nkt):
            acc = strip_dot(acc, codes_s[ki], lits_s[ki], nlit_s[ki],
                            x_s[ki])
    else:
        body = lambda a, strip: (strip_dot(a, *strip), None)
        acc, _ = jax.lax.scan(body, acc, (codes_s, lits_s, nlit_s, x_s))
    sumx = jnp.sum(x.astype(jnp.float32), axis=1, keepdims=True)   # (M, 1)
    y = scale.reshape(1, -1) * (acc - sumx * zero.reshape(1, -1))
    return y.astype(out_dtype)


def grouped_fused_decode_matmul(x, codes, literals, nlit, lut, scale, zero,
                                *, shape, tile_n: int, tile_k: int,
                                out_dtype=jnp.float32) -> jax.Array:
    """Oracle for the grouped expert megakernel.

    Per-expert fused decode→dequant→matmul over a stacked expert weight:
    x (E, M, K) capacity-gathered token blocks, codes (E, nb, slots) /
    literals (E, nb, cap, S) / nlit (E, nb) stacked tile-major planes of
    the per-expert dense ``shape = (N, K)``, scale/zero (E, N, 1).  The
    expert axis vmaps over :func:`fused_decode_matmul` (one shared LUT),
    so the semantics are exactly "strip-scan fused matmul, per plane" and
    the dense expert stack is never materialized.
    """
    fn = functools.partial(fused_decode_matmul, shape=tuple(shape),
                           tile_n=tile_n, tile_k=tile_k, out_dtype=out_dtype)
    return jax.vmap(lambda xe, c, l, nl, s, z: fn(xe, c, l, nl, lut, s, z))(
        x, codes, literals, nlit, scale, zero)


def attention_naive(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, sm_scale: float | None = None,
                    q_offset: int = 0) -> jax.Array:
    """Small-shape oracle: materializes full logits. Tests only.

    q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D); Hq % Hkv == 0.
    ``q_offset`` positions the query block inside the causal mask (decode:
    Tq=1, q_offset=cache_len-1).
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    rep = hq // hkv
    qg = q.reshape(b, hkv, rep, tq, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    sm = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("bgrqd,bgkd->bgrqk", qg, kf) * sm
    if causal:
        tk = k.shape[2]
        qpos = jnp.arange(tq) + q_offset
        kpos = jnp.arange(tk)
        mask = qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrqk,bgkv->bgrqv", p, vf)
    return out.reshape(b, hq, tq, dv).astype(q.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, sm_scale: float | None = None,
                    q_offset: int = 0, kv_chunk: int = 1024) -> jax.Array:
    """Chunked online-softmax attention in pure jnp ("jnp-flash").

    Same semantics as :func:`attention_naive` but never materializes the
    (Tq, Tk) logits — it scans KV in ``kv_chunk`` blocks carrying the
    running (max, denom, acc).  This is the XLA-path used on non-TPU
    backends and the memory model the Pallas kernel implements in VMEM;
    the dry-run's memory analysis therefore reflects flash semantics.
    GQA is grouped (no KV head repeat materialization).
    """
    b, hq, tq, d = q.shape
    hkv, tk = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    rep = hq // hkv
    sm = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    if tk <= kv_chunk:
        return attention_naive(q, k, v, causal, sm_scale, q_offset)
    n_chunks = -(-tk // kv_chunk)
    pad = n_chunks * kv_chunk - tk
    if pad:
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    else:
        kp, vp = k, v
    ks = kp.reshape(b, hkv, n_chunks, kv_chunk, d).transpose(2, 0, 1, 3, 4)
    vs = vp.reshape(b, hkv, n_chunks, kv_chunk, dv).transpose(2, 0, 1, 3, 4)
    qg = _shard_bgrtd(q.reshape(b, hkv, rep, tq, d).astype(jnp.float32))
    qpos = q_offset + jnp.arange(tq)

    def body(carry, inputs):
        m_run, l_run, acc = carry
        idx, kc, vc = inputs                       # (b,hkv,C,d)
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        s = jnp.einsum("bgrqd,bgkd->bgrqk", qg, kf) * sm
        kpos = idx * kv_chunk + jnp.arange(kv_chunk)
        valid = kpos < tk                          # padding mask
        if causal:
            valid = valid[None, :] & (qpos[:, None] >= kpos[None, :])
        else:
            valid = jnp.broadcast_to(valid[None, :], (tq, kv_chunk))
        s = jnp.where(valid[None, None, None], s, -1e30)
        m_cur = jnp.max(s, axis=-1)                # (b,g,r,q)
        m_new = jnp.maximum(m_run, m_cur)
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bgrqk,bgkv->bgrqv", p, vf)
        return (m_new, l_new, acc_new), None

    m0 = _shard_bgrtd(jnp.full((b, hkv, rep, tq), -1e30, jnp.float32))
    l0 = _shard_bgrtd(jnp.zeros((b, hkv, rep, tq), jnp.float32))
    a0 = _shard_bgrtd(jnp.zeros((b, hkv, rep, tq, dv), jnp.float32))
    body = jax.checkpoint(body)
    (m_f, l_f, acc_f), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), ks, vs))
    out = acc_f / jnp.maximum(l_f, 1e-30)[..., None]
    return out.reshape(b, hq, tq, dv).astype(q.dtype)
