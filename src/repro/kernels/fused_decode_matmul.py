"""Fused decode→dequant→matmul Pallas TPU megakernel.

Tiny-QMoE's premise is that compressed weights stay compressed until the
last possible moment.  The two-step path (``dict_decode`` then
``dequant_matmul``) betrays that on the hot loop: it writes the full dense
(N, K) uint8 weight to HBM and reads it back for the matmul — 2·N·K bytes
of HBM traffic per layer call plus a full dense-weight peak-memory spike.
This kernel fuses the dictionary decode into the matmul tile loop, exactly
as QMoE fuses its Huffman-style decode into the GPU GEMM:

  grid (M/bm, N/tile_n, G, K/(G·tile_k)), K innermost (G = optional
  column-group axis for shard-local TiledPackedLinear stacks, 1 for a
  plain PackedLinear).  Each grid step
    1. streams the ``bpt = tile_n·tile_k / block_weights`` compressed
       blocks covering the current (tile_n, tile_k) weight tile into VMEM
       (codes + literals; the decode LUT is resident in VMEM for the whole
       launch, ≤ 64k codes × S bytes),
    2. decodes them in-register — LUT row-gather for dictionary slots, an
       in-block escape-rank gather for literal slots, identical math to
       ``dict_decode._kernel``,
    3. feeds the decoded uint8 tile straight into the bf16 MXU matmul with
       the affine epilogue of ``dequant_matmul._kernel``:

           y = s · (Σ_k x·q − z·Σ_k x)      (q ≤ 255 exact in bf16)

The decoded weight never touches HBM: weight traffic drops from 2·N·K
bytes to the compressed payload, and peak working set is the compressed
planes + one VMEM tile.  This relies on the tile-major block layout of
``core.blocked_codec.encode_blocked_tiled`` — tile (j, k) of the
(N/tile_n, K/tile_k) grid owns the contiguous block rows
[t·bpt, (t+1)·bpt), t = j·n_kt + k — so the BlockSpec index maps below can
address a tile's blocks as one rectangular slab.

``grouped_fused_decode_matmul`` is the MoE variant: the grid grows a
leading expert (plane) axis so one launch sweeps a whole stacked expert
weight — the capacity-gathered token blocks (E, cap, K) against the
stacked tile-major planes (E, nb, slots) — and each grid step decodes one
(expert, tile_n, tile_k) block in VMEM inside the MXU loop.  Dense expert
weights, the dominant byte class of every QMoE-style model, never touch
HBM.

Oracles: ``ref.fused_decode_matmul`` / ``ref.grouped_fused_decode_matmul``
(same strip-wise structure in f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.codec import ESCAPE

DEFAULT_BM = 128


def _decode_tile(codes_ref, lit_ref, lut_ref, tn, tk):
    """Decode one (tile_n, tile_k) weight tile from its compressed blocks —
    the shared core of both kernels (LUT row-gather for dictionary slots,
    in-block escape-rank gather for literal slots; identical math to
    ``dict_decode._kernel``).  The uint8 result lives only in VMEM."""
    codes = codes_ref[...].astype(jnp.int32)              # (1, bpt, slots)
    codes = codes.reshape(codes.shape[-2:])               # (bpt, slots)
    lits = lit_ref[...].reshape(lit_ref.shape[-3:])       # (bpt, cap, S)
    is_esc = codes == ESCAPE
    safe = jnp.where(is_esc, 0, codes)
    from_dict = jnp.take(lut_ref[...], safe, axis=0)      # (bpt, slots, S)
    rank = jnp.clip(jnp.cumsum(is_esc.astype(jnp.int32), axis=1) - 1,
                    0, lits.shape[1] - 1)                 # (bpt, slots)
    from_lit = jnp.take_along_axis(
        lits, rank[:, :, None].astype(jnp.int32), axis=1)
    tile = jnp.where(is_esc[:, :, None], from_lit, from_dict)
    return tile.reshape(tn, tk)                           # uint8, never HBM


def _accumulate(x, q, acc_ref, sumx_ref):
    """MXU matmul against a decoded tile + running x row-sums for the
    affine epilogue: y = s · (Σ_k x·q − z·Σ_k x)  (q ≤ 255 exact in bf16)."""
    xb = x.astype(jnp.bfloat16)                           # (bm, tk)
    acc_ref[...] += jax.lax.dot_general(
        xb, q.astype(jnp.bfloat16), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (bm, tn)
    sumx_ref[...] += jnp.sum(xb.astype(jnp.float32), axis=1, keepdims=True)


def _kernel(x_ref, codes_ref, lit_ref, lut_ref, scale_ref, zero_ref, o_ref,
            acc_ref, sumx_ref):
    g_idx = pl.program_id(2)
    k_idx = pl.program_id(3)
    ng = pl.num_programs(2)
    nk = pl.num_programs(3)

    @pl.when((g_idx == 0) & (k_idx == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        sumx_ref[...] = jnp.zeros_like(sumx_ref)

    tn, tk = scale_ref.shape[0], x_ref.shape[1]
    q = _decode_tile(codes_ref, lit_ref, lut_ref, tn, tk)
    _accumulate(x_ref[...], q, acc_ref, sumx_ref)

    @pl.when((g_idx == ng - 1) & (k_idx == nk - 1))
    def _epilogue():
        s = scale_ref[...].reshape(1, -1)                 # (1, tn)
        z = zero_ref[...].reshape(1, -1)                  # (1, tn)
        o_ref[...] = (s * (acc_ref[...] - sumx_ref[...] * z)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("shape", "tile_n", "tile_k",
                                             "bm", "out_dtype", "interpret"))
def fused_decode_matmul(x: jax.Array, codes: jax.Array, literals: jax.Array,
                        lut: jax.Array, scale: jax.Array, zero: jax.Array, *,
                        shape: tuple, tile_n: int, tile_k: int,
                        bm: int = DEFAULT_BM, out_dtype=jnp.float32,
                        interpret: bool = False) -> jax.Array:
    """y = x @ dequant(decode(codes, literals)).T without a dense weight.

    x: (M, K) float, M % bm == 0; codes/literals: tile-major planes for the
    dense ``shape = (N, K)`` weight; scale/zero: (N, 1) f32.  ``nlit`` is
    not needed (the escape-rank clip makes over-reads harmless, as in
    ``dict_decode``).

    Column groups (the shard-local 2D-TP case): codes may carry a leading
    group axis — ``codes (G, nb, slots)``, ``literals (G, nb, cap, S)`` —
    where group g holds the tile-major planes of the (N, K/G) sub-weight
    covering x columns [g·K/G, (g+1)·K/G).  The grid grows a group
    dimension between N-tiles and K-strips, so the accumulator sweeps
    every (g, k) strip of an output tile before the affine epilogue fires
    once — one kernel launch per device for a whole TiledPackedLinear
    shard (a stack of column-tile planes), no per-tile HBM round trips.
    2-D codes are treated as G = 1.
    """
    n, kdim = shape
    m, k2 = x.shape
    assert k2 == kdim, (x.shape, shape)
    if codes.ndim == 2:
        codes = codes[None]
        literals = literals[None]
    groups = codes.shape[0]
    assert kdim % groups == 0, (shape, groups)
    kg = kdim // groups
    assert n % tile_n == 0 and kg % tile_k == 0, (shape, groups,
                                                  tile_n, tile_k)
    bm = min(bm, m)
    assert m % bm == 0, (m, bm)
    nnt, nkt = n // tile_n, kg // tile_k
    _, nb, slots = codes.shape
    cap, s = literals.shape[2], literals.shape[3]
    bpt = nb // (nnt * nkt)
    assert bpt * nnt * nkt == nb and bpt * slots * s == tile_n * tile_k, (
        codes.shape, literals.shape, shape, tile_n, tile_k)

    grid = (m // bm, nnt, groups, nkt)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, tile_k), lambda i, j, g, k: (i, g * nkt + k)),
            pl.BlockSpec((1, bpt, slots),
                         lambda i, j, g, k: (g, j * nkt + k, 0)),
            pl.BlockSpec((1, bpt, cap, s),
                         lambda i, j, g, k: (g, j * nkt + k, 0, 0)),
            pl.BlockSpec(lut.shape, lambda i, j, g, k: (0, 0)),  # resident
            pl.BlockSpec((tile_n, 1), lambda i, j, g, k: (j, 0)),
            pl.BlockSpec((tile_n, 1), lambda i, j, g, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, tile_n), lambda i, j, g, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, tile_n), jnp.float32),
                        pltpu.VMEM((bm, 1), jnp.float32)],
        interpret=interpret,
    )(x, codes, literals, lut, scale, zero)


def _grouped_kernel(x_ref, codes_ref, lit_ref, lut_ref, scale_ref, zero_ref,
                    o_ref, acc_ref, sumx_ref):
    k_idx = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        sumx_ref[...] = jnp.zeros_like(sumx_ref)

    tn, tk = scale_ref.shape[1], x_ref.shape[2]
    q = _decode_tile(codes_ref, lit_ref, lut_ref, tn, tk)
    _accumulate(x_ref[...].reshape(x_ref.shape[-2:]), q, acc_ref, sumx_ref)

    @pl.when(k_idx == nk - 1)
    def _epilogue():
        s = scale_ref[...].reshape(1, -1)                 # (1, tn)
        z = zero_ref[...].reshape(1, -1)                  # (1, tn)
        o_ref[...] = (s * (acc_ref[...] - sumx_ref[...] * z)
                      ).astype(o_ref.dtype).reshape(o_ref.shape)


@functools.partial(jax.jit, static_argnames=("shape", "tile_n", "tile_k",
                                             "bm", "out_dtype", "interpret"))
def grouped_fused_decode_matmul(x: jax.Array, codes: jax.Array,
                                literals: jax.Array, lut: jax.Array,
                                scale: jax.Array, zero: jax.Array, *,
                                shape: tuple, tile_n: int, tile_k: int,
                                bm: int = DEFAULT_BM, out_dtype=jnp.float32,
                                interpret: bool = False) -> jax.Array:
    """y[e] = x[e] @ dequant(decode(codes[e], literals[e])).T per expert.

    One launch for a whole MoE expert stack: x is the capacity-gathered
    token block (E, M, K), M % bm == 0 after the caller's padding; codes
    (E, nb, slots) / literals (E, nb, cap, S) are the stacked tile-major
    planes of the per-expert dense ``shape = (N, K)`` weights (uniform
    literal capacity across the stack); scale/zero (E, N, 1) f32.

    The grid is (E, M/bm, N/tile_n, K/tile_k) with the expert (plane) axis
    outermost: each step streams the compressed blocks of one
    (expert, tile_n, tile_k) weight tile into VMEM, decodes them
    in-register, and feeds the uint8 tile straight into the MXU — the same
    per-tile pipeline as :func:`fused_decode_matmul`, swept across expert
    planes, so dense expert weights never exist in HBM and peak HBM stays
    "compressed experts + gathered activations + one VMEM tile".
    """
    n, kdim = shape
    e, m, k2 = x.shape
    assert k2 == kdim, (x.shape, shape)
    assert codes.ndim == 3 and codes.shape[0] == e, (codes.shape, x.shape)
    assert n % tile_n == 0 and kdim % tile_k == 0, (shape, tile_n, tile_k)
    bm = min(bm, m)
    assert m % bm == 0, (m, bm)
    nnt, nkt = n // tile_n, kdim // tile_k
    _, nb, slots = codes.shape
    cap, s = literals.shape[2], literals.shape[3]
    bpt = nb // (nnt * nkt)
    assert bpt * nnt * nkt == nb and bpt * slots * s == tile_n * tile_k, (
        codes.shape, literals.shape, shape, tile_n, tile_k)

    grid = (e, m // bm, nnt, nkt)
    return pl.pallas_call(
        _grouped_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, tile_k), lambda ei, i, j, k: (ei, i, k)),
            pl.BlockSpec((1, bpt, slots),
                         lambda ei, i, j, k: (ei, j * nkt + k, 0)),
            pl.BlockSpec((1, bpt, cap, s),
                         lambda ei, i, j, k: (ei, j * nkt + k, 0, 0)),
            pl.BlockSpec(lut.shape, lambda ei, i, j, k: (0, 0)),  # resident
            pl.BlockSpec((1, tile_n, 1), lambda ei, i, j, k: (ei, j, 0)),
            pl.BlockSpec((1, tile_n, 1), lambda ei, i, j, k: (ei, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, tile_n),
                               lambda ei, i, j, k: (ei, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, tile_n), jnp.float32),
                        pltpu.VMEM((bm, 1), jnp.float32)],
        interpret=interpret,
    )(x, codes, literals, lut, scale, zero)
