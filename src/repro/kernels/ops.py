"""jit'd public wrappers around the Pallas kernels.

Dispatch policy:
  * TPU backend       → Pallas kernel (compiled).
  * CPU/GPU backend   → pure-jnp oracle (``ref.py``) — same semantics; this
    preserves the paper's run-anywhere property.  Tests force
    ``impl='pallas_interpret'`` to validate the kernel bodies on CPU.

All wrappers pad to tile multiples and slice back, so callers never care
about block alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from . import dequant_matmul as _dqmm
from . import dict_decode as _dd
from . import flash_attention as _fa
from . import fused_decode_matmul as _fdm

# 'auto' | 'ref' | 'pallas' | 'pallas_interpret' — plus 'unfused' for
# decode_dequant_matmul only (force the legacy two-step decode→matmul path).
Impl = str


def _use_pallas(impl: Impl) -> tuple[bool, bool]:
    """-> (use_kernel, interpret)"""
    if impl == "ref":
        return False, False
    if impl == "pallas":
        return True, False
    if impl == "pallas_interpret":
        return True, True
    # auto
    if jax.default_backend() == "tpu":
        return True, False
    return False, False


def _pad_to(x: jax.Array, axis: int, mult: int, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), size


def dequant_matmul(x, wq, scale, zero, *, out_dtype=jnp.float32,
                   impl: Impl = "auto", bm=None, bn=None, bk=None):
    """y = x @ dequant(wq).T with per-channel affine (scale, zero).

    x: (..., K) float; wq: (N, K) uint8; scale/zero: (N, 1).
    Leading dims of x are flattened to M.
    """
    use_kernel, interpret = _use_pallas(impl)
    lead = x.shape[:-1]
    kdim = x.shape[-1]
    x2 = x.reshape(-1, kdim)
    if not use_kernel:
        y = ref.dequant_matmul(x2, wq, scale, zero, out_dtype)
        return y.reshape(*lead, wq.shape[0])
    kw = {}
    if bm: kw["bm"] = bm
    if bn: kw["bn"] = bn
    if bk: kw["bk"] = bk
    bm_ = kw.get("bm", _dqmm.DEFAULT_BM)
    bn_ = kw.get("bn", _dqmm.DEFAULT_BN)
    bk_ = kw.get("bk", _dqmm.DEFAULT_BK)
    x2, m0 = _pad_to(x2, 0, min(bm_, max(x2.shape[0], 1)))
    x2, _ = _pad_to(x2, 1, min(bk_, kdim))
    wqp, n0 = _pad_to(wq, 0, min(bn_, wq.shape[0]))
    wqp, _ = _pad_to(wqp, 1, min(bk_, kdim))
    sp, _ = _pad_to(scale, 0, min(bn_, scale.shape[0]))
    zp, _ = _pad_to(zero, 0, min(bn_, zero.shape[0]))
    y = _dqmm.dequant_matmul(x2, wqp, sp, zp, out_dtype=out_dtype,
                             interpret=interpret, **kw)
    return y[:m0, :n0].reshape(*lead, n0)


def dict_decode(codes, literals, nlit, lut, *, impl: Impl = "auto",
                chunk: int | None = None):
    """(nb, slots) uint16 → (nb, slots·S) uint8."""
    use_kernel, interpret = _use_pallas(impl)
    if not use_kernel:
        return ref.dict_decode(codes, literals, nlit, lut)
    ch = chunk or _dd.DEFAULT_CHUNK
    nb = codes.shape[0]
    ch = min(ch, nb)
    # Pad the block axis to a chunk multiple and slice back, instead of
    # shrinking the chunk to a divisor of nb (which silently degraded to
    # chunk=1 — one grid step per block — for prime block counts).  Padded
    # rows decode to LUT row 0 garbage and are dropped by the slice.
    codes, nb0 = _pad_to(codes, 0, ch)
    literals, _ = _pad_to(literals, 0, ch)
    out = _dd.dict_decode(codes, literals, nlit, lut, chunk=ch,
                          interpret=interpret)
    return out[:nb0]


def flash_attention(q, k, v, *, causal=True, sm_scale=None, q_offset=0,
                    impl: Impl = "auto", bq=None, bk=None, kv_chunk=None):
    """(B, Hq, Tq, D) × (B, Hkv, Tk, D) → (B, Hq, Tq, D)."""
    use_kernel, interpret = _use_pallas(impl)
    if not use_kernel:
        kw = {"kv_chunk": kv_chunk} if kv_chunk else {}
        return ref.flash_attention(q, k, v, causal=causal,
                                   sm_scale=sm_scale, q_offset=q_offset, **kw)
    kw = {}
    if bq: kw["bq"] = bq
    if bk: kw["bk"] = bk
    return _fa.flash_attention(q, k, v, causal=causal, sm_scale=sm_scale,
                               q_offset=q_offset, interpret=interpret, **kw)


def _mesh_device_count() -> int:
    from repro.sharding.partition import _current_axis_sizes
    axis_sizes, _ = _current_axis_sizes()
    n = 1
    for v in axis_sizes.values():
        n *= v
    return n


def decode_dequant_matmul(x, packed, lut, *, out_dtype=jnp.bfloat16,
                          impl: Impl = "auto"):
    """Compressed-weight matmul: the paper's serving hot path.

    ``packed`` is a repro.core.compressed.PackedLinear (single layer).

    Dispatch: when the planes carry the tile-major layout
    (``packed.tile_n > 0``) this routes to the fused decode→dequant→matmul
    megakernel (``fused_decode_matmul`` on TPU, its strip-scan oracle
    ``ref.fused_decode_matmul`` elsewhere) — the dense weight never
    materializes.  ``impl='unfused'`` forces the legacy two-step path
    (decode to HBM, then ``dequant_matmul``), which also serves as the
    fallback for linear-layout planes and for sharded meshes (the fused
    kernel is the single-device on-device-serving path; its planes would
    need a shard_map wrapper to split the grid across a mesh — see
    ROADMAP open items).
    """
    unfused = impl == "unfused"
    inner_impl = "auto" if unfused else impl
    tile_n = getattr(packed, "tile_n", 0)
    if (not unfused and tile_n and packed.codes.ndim == 2
            and _mesh_device_count() == 1):
        return _fused_decode_matmul(x, packed, lut, out_dtype=out_dtype,
                                    impl=impl)
    return _decode_dequant_matmul_unfused(x, packed, lut,
                                          out_dtype=out_dtype,
                                          impl=inner_impl)


def _fused_decode_matmul(x, packed, lut, *, out_dtype, impl: Impl):
    """Megakernel path — decoded weight tiles live only in VMEM/registers."""
    use_kernel, interpret = _use_pallas(impl)
    n, kdim = packed.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, kdim)
    if not use_kernel:
        y = ref.fused_decode_matmul(
            x2, packed.codes, packed.literals, packed.nlit, lut,
            packed.scale, packed.zero, shape=tuple(packed.shape),
            tile_n=packed.tile_n, tile_k=packed.tile_k, out_dtype=out_dtype)
        return y.reshape(*lead, n)
    bm = min(_fdm.DEFAULT_BM, max(x2.shape[0], 1))
    x2, m0 = _pad_to(x2, 0, bm)
    y = _fdm.fused_decode_matmul(
        x2, packed.codes, packed.literals, lut, packed.scale, packed.zero,
        shape=tuple(packed.shape), tile_n=packed.tile_n,
        tile_k=packed.tile_k, bm=bm, out_dtype=out_dtype,
        interpret=interpret)
    return y[:m0].reshape(*lead, n)


def _decode_dequant_matmul_unfused(x, packed, lut, *, out_dtype,
                                   impl: Impl):
    """Legacy two-step path: decode the full weight, then dequant-matmul.

    Pays 2·N·K bytes of dense-weight HBM traffic per call (write decoded,
    read for the matmul); kept for sharded serving and as the
    ``impl='unfused'`` baseline the benchmarks compare against.
    """
    from repro.sharding.partition import constrain
    packed = packed.degather()   # gather compressed bytes, not f32 (§Perf D1)
    n, kdim = packed.shape
    wq_flat = dict_decode(packed.codes, packed.literals, packed.nlit, lut,
                          impl=impl)
    if getattr(packed, "tile_n", 0):
        from repro.core.blocked_codec import untile_flat
        wq = untile_flat(wq_flat.reshape(-1)[: n * kdim], (n, kdim),
                         packed.tile_n, packed.tile_k)
    else:
        wq = wq_flat.reshape(-1)[: n * kdim].reshape(n, kdim)
    if packed.row_parallel:
        # wo/w_down: contraction dim must carry the model sharding — decode
        # leaves rows:model; reshard the u8 weight (not the f32
        # activations, which SPMD otherwise gathers at 4-13 GiB/layer;
        # §Perf P2), then the dot partial-sums into the standard
        # row-parallel output all-reduce.
        wq = constrain(wq, None, "model")
    return dequant_matmul(x, wq, packed.scale, packed.zero,
                          out_dtype=out_dtype, impl=impl)


def tiled_decode_dequant_matmul(x, packed, lut, *, out_dtype=jnp.bfloat16,
                                impl: Impl = "auto"):
    """2D-TP path (§Perf D2): every device decodes its permanently-resident
    (out/model × in/data) compressed tile; x reshards its feature dim onto
    data (MB-scale all-to-all) and the dot's partial sums reduce over data.
    No weight collectives at all.

    ``packed`` is a repro.core.compressed.TiledPackedLinear.
    """
    from repro.sharding.partition import constrain
    n, kdim = packed.shape
    w = packed.materialize(lut, dtype=x.dtype)        # (n, kdim), in-sharded
    w = constrain(w, "model", ("pod", "data"))
    xs = constrain(x, *([None] * (x.ndim - 1)), ("pod", "data"))
    y = jnp.einsum("...k,nk->...n", xs, w)
    return constrain(y.astype(out_dtype),
                     *([None] * (x.ndim - 1)), "model")
