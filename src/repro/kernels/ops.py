"""jit'd public wrappers around the Pallas kernels.

Dispatch policy:
  * TPU backend       → Pallas kernel (compiled).
  * CPU/GPU backend   → pure-jnp oracle (``ref.py``) — same semantics; this
    preserves the paper's run-anywhere property.  Tests force
    ``impl='pallas_interpret'`` to validate the kernel bodies on CPU.

All wrappers pad to tile multiples and slice back, so callers never care
about block alignment.
"""
from __future__ import annotations

import collections
import enum
import functools
import os

import jax
import jax.numpy as jnp

from . import ref
from . import dequant_matmul as _dqmm
from . import dict_decode as _dd
from . import flash_attention as _fa
from . import fused_decode_matmul as _fdm


class Impl(str, enum.Enum):
    """The single source of truth for kernel-dispatch impl values.

    Backend selectors: ``AUTO`` (backend default — kernel on TPU, jnp
    oracle elsewhere), ``REF`` (force the oracle), ``PALLAS`` (force the
    compiled kernel), ``PALLAS_INTERPRET`` (kernel bodies in interpret
    mode — CI's CPU kernel job).  Degradation rungs, for the
    compressed-matmul wrappers only: ``UNFUSED`` (legacy two-step
    decode→matmul path) and ``MATERIALIZE`` (pure-jnp decode + dense
    einsum, no Pallas anywhere — serve/resilience.py's last functional
    rung).

    A ``str`` subclass, so every existing ``impl='unfused'`` call site —
    and jit static-argnum hashing — keeps working; dispatch code compares
    against these members instead of scattered string literals.
    """
    AUTO = "auto"
    REF = "ref"
    PALLAS = "pallas"
    PALLAS_INTERPRET = "pallas_interpret"
    UNFUSED = "unfused"
    MATERIALIZE = "materialize"

    __str__ = str.__str__          # f"{Impl.UNFUSED}" -> "unfused"


VALID_IMPLS = frozenset(i.value for i in Impl)

# The resilience ladder's rung names, from the same source of truth the
# dispatch lever uses.  'fused' is not an impl — it serves with the
# session default ('auto' → megakernel dispatch); the fallback rungs pin
# the corresponding Impl lever (serve/resilience.py::_RUNG_IMPL).
FUSED_RUNG = "fused"
DEFAULT_LADDER = (FUSED_RUNG, Impl.UNFUSED.value, Impl.MATERIALIZE.value)

# What 'auto' resolves to before the backend check.  CI's interpret-mode
# kernel job sets REPRO_TEST_IMPL=pallas_interpret (via tests/conftest.py)
# so every auto-dispatched call exercises the Pallas kernel bodies on the
# CPU runner instead of the jnp oracles.  Lenient at import (a bad env
# var falls back to 'auto' instead of breaking every import);
# ``set_default_impl`` is the strict entry point.
_DEFAULT_IMPL = os.environ.get("REPRO_TEST_IMPL", "auto")
if _DEFAULT_IMPL not in VALID_IMPLS:
    _DEFAULT_IMPL = "auto"


def set_default_impl(impl) -> None:
    """Override what ``impl='auto'`` resolves to (tests/CI, the resilience
    ladder's fallback lever).  Validates against :class:`Impl`."""
    global _DEFAULT_IMPL
    _DEFAULT_IMPL = Impl(impl).value


def _resolve_unfused(impl: Impl) -> Impl:
    """'auto' resolves to 'unfused'/'materialize' when the session default
    says so — the lever that forces a degradation rung (or the benchmark
    baseline) through call sites (``generate``) that don't thread an
    ``impl`` argument."""
    if impl == "auto" and _DEFAULT_IMPL in ("unfused", "materialize"):
        return _DEFAULT_IMPL
    return impl


# Trace-time dispatch probe: which decode→dequant→matmul path each call
# took.  Bodies run once per jit trace, so tests can clear this, run a
# sharded matmul, and assert e.g. 'fused_shard_map' was taken (the CI
# acceptance check that sharded paths never silently fall back to the
# dense-materializing two-step path).
DISPATCH_COUNTS = collections.Counter()

# The shard-mapped fused PackedLinear path replicates x over the weight
# axes inside its shard_map (in_specs P(drow, None)), so it trades an
# m·K activation gather for the two-step path's 2·N·K dense-weight HBM
# round trip.  Decode/small-batch shapes win (m ≲ N); 32k-prefill shapes
# lose badly (m ≫ N: +19 GiB collectives, +6 GiB HBM per step measured on
# deepseek-v2-lite prefill_32k×512dev).  Gate: fused shard_map only when
# m ≤ max(N, this floor); the floor keeps decode-scale row counts (and
# the 8-device CI shapes) on the fused path for small-N layers.  The
# grouped expert path is exempt — its xe is expert-sharded, never
# replicated.
FUSED_SHARD_MAP_MAX_M = 512


def _use_pallas(impl: Impl) -> tuple[bool, bool]:
    """-> (use_kernel, interpret)"""
    if impl == "auto":
        impl = _DEFAULT_IMPL
    if impl == "ref":
        return False, False
    if impl == "pallas":
        return True, False
    if impl == "pallas_interpret":
        return True, True
    # auto
    if jax.default_backend() == "tpu":
        return True, False
    return False, False


def _pad_to(x: jax.Array, axis: int, mult: int, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), size


def dequant_matmul(x, wq, scale, zero, *, out_dtype=jnp.float32,
                   impl: Impl = "auto", bm=None, bn=None, bk=None):
    """y = x @ dequant(wq).T with per-channel affine (scale, zero).

    x: (..., K) float; wq: (N, K) uint8; scale/zero: (N, 1).
    Leading dims of x are flattened to M.
    """
    use_kernel, interpret = _use_pallas(impl)
    lead = x.shape[:-1]
    kdim = x.shape[-1]
    x2 = x.reshape(-1, kdim)
    if not use_kernel:
        y = ref.dequant_matmul(x2, wq, scale, zero, out_dtype)
        return y.reshape(*lead, wq.shape[0])
    kw = {}
    if bm: kw["bm"] = bm
    if bn: kw["bn"] = bn
    if bk: kw["bk"] = bk
    bm_ = kw.get("bm", _dqmm.DEFAULT_BM)
    bn_ = kw.get("bn", _dqmm.DEFAULT_BN)
    bk_ = kw.get("bk", _dqmm.DEFAULT_BK)
    x2, m0 = _pad_to(x2, 0, min(bm_, max(x2.shape[0], 1)))
    x2, _ = _pad_to(x2, 1, min(bk_, kdim))
    wqp, n0 = _pad_to(wq, 0, min(bn_, wq.shape[0]))
    wqp, _ = _pad_to(wqp, 1, min(bk_, kdim))
    sp, _ = _pad_to(scale, 0, min(bn_, scale.shape[0]))
    zp, _ = _pad_to(zero, 0, min(bn_, zero.shape[0]))
    y = _dqmm.dequant_matmul(x2, wqp, sp, zp, out_dtype=out_dtype,
                             interpret=interpret, **kw)
    return y[:m0, :n0].reshape(*lead, n0)


def dict_decode(codes, literals, nlit, lut, *, impl: Impl = "auto",
                chunk: int | None = None):
    """(nb, slots) uint16 → (nb, slots·S) uint8."""
    use_kernel, interpret = _use_pallas(impl)
    if not use_kernel:
        return ref.dict_decode(codes, literals, nlit, lut)
    ch = chunk or _dd.DEFAULT_CHUNK
    nb = codes.shape[0]
    ch = min(ch, nb)
    # Pad the block axis to a chunk multiple and slice back, instead of
    # shrinking the chunk to a divisor of nb (which silently degraded to
    # chunk=1 — one grid step per block — for prime block counts).  Padded
    # rows decode to LUT row 0 garbage and are dropped by the slice.
    codes, nb0 = _pad_to(codes, 0, ch)
    literals, _ = _pad_to(literals, 0, ch)
    out = _dd.dict_decode(codes, literals, nlit, lut, chunk=ch,
                          interpret=interpret)
    return out[:nb0]


def flash_attention(q, k, v, *, causal=True, sm_scale=None, q_offset=0,
                    impl: Impl = "auto", bq=None, bk=None, kv_chunk=None):
    """(B, Hq, Tq, D) × (B, Hkv, Tk, D) → (B, Hq, Tq, D)."""
    use_kernel, interpret = _use_pallas(impl)
    if not use_kernel:
        kw = {"kv_chunk": kv_chunk} if kv_chunk else {}
        return ref.flash_attention(q, k, v, causal=causal,
                                   sm_scale=sm_scale, q_offset=q_offset, **kw)
    kw = {}
    if bq: kw["bq"] = bq
    if bk: kw["bk"] = bk
    return _fa.flash_attention(q, k, v, causal=causal, sm_scale=sm_scale,
                               q_offset=q_offset, interpret=interpret, **kw)


def _mesh_state():
    """(axis_sizes, mesh, total_devices) of the trace-time mesh — the
    shared preamble of both fused-dispatch decisions below."""
    from repro.sharding.partition import current_mesh
    axis_sizes, mesh = current_mesh()
    ndev = 1
    for v in axis_sizes.values():
        ndev *= v
    return axis_sizes, mesh, ndev


def _is_concrete_mesh(mesh) -> bool:
    from jax.sharding import Mesh
    return isinstance(mesh, Mesh)


def decode_dequant_matmul(x, packed, lut, *, out_dtype=jnp.bfloat16,
                          impl: Impl = "auto"):
    """Compressed-weight matmul: the paper's serving hot path.

    ``packed`` is a repro.core.compressed.PackedLinear (single layer).

    Dispatch (tile-major planes, ``packed.tile_n > 0``): fused is the
    invariant — the dense weight never materializes in HBM.
      * no mesh / 1 device  → fused megakernel directly
        (``fused_decode_matmul`` on TPU, its strip-scan oracle
        ``ref.fused_decode_matmul`` elsewhere).
      * active concrete mesh → shard_map wrapper: the tile-major block
        axis splits over the weight-sharding axes (pod, model) in whole
        out-tile bands — requires ``(N / tile_n) % (pod·model) == 0``,
        which ``blocked_codec.choose_fused_tiles(shards=...)`` arranges —
        and each device runs the fused grid over its resident compressed
        slab; x replicates over (pod, model) (rows stay data-sharded when
        divisible) and the output comes back column-sharded on
        (pod, model).  Plane gathers (FSDP'd storage) move compressed
        bytes, never the dense weight — same D1 degather economics as the
        two-step path.
    Fallbacks to the legacy two-step path (decode to HBM, then
    ``dequant_matmul``): linear-layout planes (tile_n == 0), stacked
    planes outside a scan, out-tile counts that don't divide the weight
    axes, abstract meshes, prefill-scale row counts under a mesh
    (m > max(N, ``FUSED_SHARD_MAP_MAX_M``) — the shard_map's x
    replication would outweigh the dense round-trip; see the constant),
    and ``impl='unfused'`` (the benchmark baseline).  ``impl='materialize'``
    (probe 'materialize') bypasses every Pallas kernel: pure-jnp decode +
    dequantize to the dense weight, plain einsum — the resilience ladder's
    last functional rung when both kernel paths are faulting.
    """
    impl = _resolve_unfused(impl)
    if impl == "materialize":
        DISPATCH_COUNTS["materialize"] += 1
        w = packed.materialize(lut, dtype=x.dtype)
        return jnp.einsum("...k,nk->...n", x, w).astype(out_dtype)
    unfused = impl == "unfused"
    inner_impl = "auto" if unfused else impl
    tile_n = getattr(packed, "tile_n", 0)
    if not unfused and tile_n and packed.codes.ndim == 2:
        axis_sizes, mesh, ndev = _mesh_state()
        if ndev <= 1:
            DISPATCH_COUNTS["fused"] += 1
            return _fused_decode_matmul(x, packed, lut, out_dtype=out_dtype,
                                        impl=impl)
        waxes = tuple(a for a in ("pod", "model")
                      if axis_sizes.get(a, 1) > 1)
        wsize = 1
        for a in waxes:
            wsize *= axis_sizes[a]
        m_rows = x.size // x.shape[-1] if x.shape[-1] else 0
        if (_is_concrete_mesh(mesh)
                and (packed.shape[0] // tile_n) % wsize == 0
                and m_rows <= max(packed.shape[0], FUSED_SHARD_MAP_MAX_M)):
            DISPATCH_COUNTS["fused_shard_map"] += 1
            return _fused_decode_matmul_sharded(
                x, packed, lut, out_dtype=out_dtype, impl=impl,
                mesh=mesh, axis_sizes=axis_sizes, waxes=waxes)
    DISPATCH_COUNTS["unfused"] += 1
    return _decode_dequant_matmul_unfused(x, packed, lut,
                                          out_dtype=out_dtype,
                                          impl=inner_impl)


def _fused_tile_matmul(x2, codes, literals, nlit, lut, scale, zero, *,
                       shape, tile_n, tile_k, out_dtype, impl: Impl):
    """Fused matmul over tile-major planes, shard-local workhorse.

    ``codes`` may carry a leading column-group axis (G, nb, slots) — the
    shard-local stack of a TiledPackedLinear — in which case group g
    covers x columns [g·K/G, (g+1)·K/G) of ``shape = (N, K)``.  Runs the
    Pallas megakernel (grouped grid) or the strip-scan oracle, summing
    per-group partial affines in f32 (exact: the affine epilogue is
    linear in the accumulators).
    """
    use_kernel, interpret = _use_pallas(impl)
    n, ktot = shape
    m = x2.shape[0]
    if use_kernel:
        bm = min(_fdm.DEFAULT_BM, max(m, 1))
        x2p, m0 = _pad_to(x2, 0, bm)
        y = _fdm.fused_decode_matmul(
            x2p, codes, literals, lut, scale, zero, shape=tuple(shape),
            tile_n=tile_n, tile_k=tile_k, bm=bm, out_dtype=out_dtype,
            interpret=interpret)
        return y[:m0]
    if codes.ndim == 2:
        return ref.fused_decode_matmul(
            x2, codes, literals, nlit, lut, scale, zero,
            shape=tuple(shape), tile_n=tile_n, tile_k=tile_k,
            out_dtype=out_dtype)
    groups = codes.shape[0]
    kg = ktot // groups
    acc = jnp.zeros((m, n), jnp.float32)
    for g in range(groups):   # small static count: unrolled like K-strips
        acc = acc + ref.fused_decode_matmul(
            x2[:, g * kg:(g + 1) * kg], codes[g], literals[g], nlit[g],
            lut, scale, zero, shape=(n, kg), tile_n=tile_n, tile_k=tile_k,
            out_dtype=jnp.float32)
    return acc.astype(out_dtype)


def _fused_decode_matmul(x, packed, lut, *, out_dtype, impl: Impl):
    """Megakernel path — decoded weight tiles live only in VMEM/registers."""
    n, kdim = packed.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, kdim)
    y = _fused_tile_matmul(x2, packed.codes, packed.literals, packed.nlit,
                           lut, packed.scale, packed.zero,
                           shape=tuple(packed.shape), tile_n=packed.tile_n,
                           tile_k=packed.tile_k, out_dtype=out_dtype,
                           impl=impl)
    return y.reshape(*lead, n)


def _fused_decode_matmul_sharded(x, packed, lut, *, out_dtype, impl: Impl,
                                 mesh, axis_sizes, waxes):
    """shard_map-wrapped fused megakernel for a mesh-sharded PackedLinear.

    The tile-major block axis (and scale/zero rows) split over ``waxes``
    (the pod/model weight axes) in whole out-tile bands; each device runs
    the fused grid over its shard-local (N/wsize, K) compressed slab.  The
    output is column-parallel — y's feature dim lands sharded on
    ``waxes``, no psum needed — and x's rows stay on the data axis when
    they divide.  For a row_parallel container the math is identical
    (same dense y); only the output layout differs, and the caller's next
    constraint reshards activation bytes, never weight bytes.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n, kdim = packed.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, kdim)
    m = x2.shape[0]
    wsize = 1
    for a in waxes:
        wsize *= axis_sizes[a]
    n_loc = n // wsize
    wspec = waxes if len(waxes) > 1 else (waxes[0] if waxes else None)
    dsize = axis_sizes.get("data", 1)
    drow = "data" if (dsize > 1 and m % dsize == 0) else None
    tile_n, tile_k = packed.tile_n, packed.tile_k

    def local_fn(xl, codes, lits, nlit, lutl, scale, zero):
        return _fused_tile_matmul(xl, codes, lits, nlit, lutl, scale, zero,
                                  shape=(n_loc, kdim), tile_n=tile_n,
                                  tile_k=tile_k, out_dtype=out_dtype,
                                  impl=impl)

    y = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(drow, None), P(wspec, None), P(wspec, None, None),
                  P(wspec), P(None, None), P(wspec, None), P(wspec, None)),
        out_specs=P(drow, wspec),
        check_rep=False,
    )(x2, packed.codes, packed.literals, packed.nlit, lut,
      packed.scale, packed.zero)
    return y.reshape(*lead, n)


def _decode_dequant_matmul_unfused(x, packed, lut, *, out_dtype,
                                   impl: Impl):
    """Legacy two-step path: decode the full weight, then dequant-matmul.

    Pays 2·N·K bytes of dense-weight HBM traffic per call (write decoded,
    read for the matmul); kept for sharded serving and as the
    ``impl='unfused'`` baseline the benchmarks compare against.
    """
    from repro.sharding.partition import constrain
    packed = packed.degather()   # gather compressed bytes, not f32 (§Perf D1)
    n, kdim = packed.shape
    wq_flat = dict_decode(packed.codes, packed.literals, packed.nlit, lut,
                          impl=impl)
    if getattr(packed, "tile_n", 0):
        from repro.core.blocked_codec import untile_flat
        wq = untile_flat(wq_flat.reshape(-1)[: n * kdim], (n, kdim),
                         packed.tile_n, packed.tile_k)
    else:
        wq = wq_flat.reshape(-1)[: n * kdim].reshape(n, kdim)
    if packed.row_parallel:
        # wo/w_down: contraction dim must carry the model sharding — decode
        # leaves rows:model; reshard the u8 weight (not the f32
        # activations, which SPMD otherwise gathers at 4-13 GiB/layer;
        # §Perf P2), then the dot partial-sums into the standard
        # row-parallel output all-reduce.
        wq = constrain(wq, None, "model")
    return dequant_matmul(x, wq, packed.scale, packed.zero,
                          out_dtype=out_dtype, impl=impl)


def tiled_decode_dequant_matmul(x, packed, lut, *, out_dtype=jnp.bfloat16,
                                impl: Impl = "auto"):
    """2D-TP path (§Perf D2): every device owns a permanently-resident
    (out/model × in/data) compressed tile; x reshards its feature dim onto
    data (MB-scale all-to-all) and the dot's partial sums reduce over data.
    No weight collectives at all.

    ``packed`` is a repro.core.compressed.TiledPackedLinear.

    Dispatch: when the per-tile planes carry the fused tile-major layout
    (``packed.tile_n > 0``) the fused megakernel is the invariant here
    too — no per-device dense tile is ever materialized:
      * no mesh / 1 device → one grouped-grid fused call over the whole
        column-tile stack.
      * active concrete mesh → shard_map: tile axis splits on data, the
        per-tile block axis on model (whole out-tile bands — requires
        ``tiles % data == 0`` and ``(out / tile_n) % model == 0``, which
        ``encode_tiled_planes(tile='auto', shards=(model, 1))``
        arranges); each device runs the fused grid over its resident
        (out/model × in/data) compressed slab and the row-parallel psum
        over data runs in the epilogue.  Weights cross no links; only
        activations move.
    Fallback (linear per-tile layout, stacked planes outside a scan,
    non-divisible tile counts, abstract meshes, ``impl='unfused'``):
    decode + dequantize the dense weight per device, then einsum — the
    legacy two-step 2D-TP path below.
    """
    from repro.sharding.partition import constrain
    impl = _resolve_unfused(impl)
    # 'materialize' shares the dense-einsum fallback below (it already
    # decodes with the pure-jnp codec) but gets its own probe key.
    unfused = impl in ("unfused", "materialize")
    inner_impl = "auto" if unfused else impl
    tile_n = getattr(packed, "tile_n", 0)
    n, kdim = packed.shape
    if not unfused and tile_n and packed.codes.ndim == 3:
        axis_sizes, mesh, ndev = _mesh_state()
        if ndev <= 1:
            DISPATCH_COUNTS["tiled_fused"] += 1
            lead = x.shape[:-1]
            x2 = x.reshape(-1, kdim)
            y = _fused_tile_matmul(
                x2, packed.codes, packed.literals, packed.nlit, lut,
                packed.scale, packed.zero, shape=(n, kdim),
                tile_n=tile_n, tile_k=packed.tile_k,
                out_dtype=out_dtype, impl=impl)
            return y.reshape(*lead, n)
        dsize = axis_sizes.get("data", 1)
        msize = axis_sizes.get("model", 1)
        if (_is_concrete_mesh(mesh) and packed.tiles % dsize == 0
                and (n // tile_n) % msize == 0):
            DISPATCH_COUNTS["tiled_fused_shard_map"] += 1
            return _tiled_fused_sharded(x, packed, lut, out_dtype=out_dtype,
                                        impl=impl, mesh=mesh,
                                        axis_sizes=axis_sizes)
    DISPATCH_COUNTS["tiled_materialize" if impl == "materialize"
                    else "tiled_unfused"] += 1
    w = packed.materialize(lut, dtype=x.dtype)        # (n, kdim), in-sharded
    w = constrain(w, "model", ("pod", "data"))
    xs = constrain(x, *([None] * (x.ndim - 1)), ("pod", "data"))
    y = jnp.einsum("...k,nk->...n", xs, w)
    return constrain(y.astype(out_dtype),
                     *([None] * (x.ndim - 1)), "model")


def _tiled_fused_sharded(x, packed, lut, *, out_dtype, impl: Impl,
                         mesh, axis_sizes):
    """shard_map-wrapped fused megakernel for the TiledPackedLinear 2D-TP
    layout: tile (column-group) axis on data, block axis on model, pods
    replicate weights and carry x rows.  Each device decodes nothing to
    HBM — its grouped fused grid streams the resident compressed tiles —
    and the contraction's partial sums psum over data (the row-parallel
    epilogue), leaving y column-sharded on model.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n, kdim = packed.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, kdim)
    m = x2.shape[0]
    daxis = "data" if "data" in axis_sizes else None
    maxis = "model" if "model" in axis_sizes else None
    msize = axis_sizes.get("model", 1)
    psize = axis_sizes.get("pod", 1)
    prow = "pod" if ("pod" in axis_sizes and psize > 1
                     and m % psize == 0) else None
    n_loc = n // msize
    in_loc = kdim // axis_sizes.get("data", 1)
    tile_n, tile_k = packed.tile_n, packed.tile_k

    def local_fn(xl, codes, lits, nlit, lutl, scale, zero):
        y = _fused_tile_matmul(xl, codes, lits, nlit, lutl, scale, zero,
                               shape=(n_loc, in_loc), tile_n=tile_n,
                               tile_k=tile_k, out_dtype=jnp.float32,
                               impl=impl)
        if daxis is not None:
            y = jax.lax.psum(y, daxis)    # row-parallel epilogue reduce
        return y.astype(out_dtype)

    y = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(prow, daxis), P(daxis, maxis, None),
                  P(daxis, maxis, None, None), P(daxis, maxis),
                  P(None, None), P(maxis, None), P(maxis, None)),
        out_specs=P(prow, maxis),
        check_rep=False,
    )(x2, packed.codes, packed.literals, packed.nlit, lut,
      packed.scale, packed.zero)
    return y.reshape(*lead, n)


def grouped_fused_local(xe, packed, lut, *, out_dtype=jnp.bfloat16,
                        impl: Impl = "auto"):
    """Shard-local grouped expert fused matmul — no mesh dispatch.

    ``packed`` is a stacked PackedLinear (leading expert axis on every
    plane, tile-major layout); ``xe`` the matching (E, cap, K) token
    blocks.  Runs the grouped Pallas megakernel (TPU/interpret) or its
    vmapped strip-scan oracle directly, so it is safe inside shard_map
    bodies that already own only their expert shard (the local-routing
    MoE); callers outside shard_map should use
    :func:`grouped_decode_dequant_matmul`, which adds mesh dispatch and
    the probe counters.
    """
    tile_n, tile_k = packed.tile_n, packed.tile_k
    assert tile_n and packed.codes.ndim == 3, (tile_n, packed.codes.shape)
    use_kernel, interpret = _use_pallas(impl)
    if use_kernel:
        m = xe.shape[1]
        bm = min(_fdm.DEFAULT_BM, max(m, 1))
        xp, m0 = _pad_to(xe, 1, bm)
        y = _fdm.grouped_fused_decode_matmul(
            xp, packed.codes, packed.literals, lut, packed.scale,
            packed.zero, shape=tuple(packed.shape), tile_n=tile_n,
            tile_k=tile_k, bm=bm, out_dtype=out_dtype, interpret=interpret)
        return y[:, :m0]
    return ref.grouped_fused_decode_matmul(
        xe, packed.codes, packed.literals, packed.nlit, lut,
        packed.scale, packed.zero, shape=tuple(packed.shape),
        tile_n=tile_n, tile_k=tile_k, out_dtype=out_dtype)


def grouped_decode_dequant_matmul(xe, packed, lut, *,
                                  out_dtype=jnp.bfloat16,
                                  impl: Impl = "auto"):
    """Per-expert compressed matmul y[e] = x[e] @ W[e].T — the MoE hot path.

    ``packed`` is a repro.core.compressed.PackedLinear whose planes carry a
    leading expert axis (codes (E, nb, slots), scale (E, N, 1), …); ``xe``
    the capacity-gathered token blocks (E, cap, K) of the same expert
    order.  This is the layer that keeps QMoE-class expert stacks —
    where ~all the model's bytes live — compressed-resident in HBM.

    Dispatch (tile-major planes, ``packed.tile_n > 0``):
      * no mesh / 1 device  → grouped megakernel directly (expert grid
        axis; ``fused_decode_matmul.grouped_fused_decode_matmul`` on TPU,
        the vmapped strip-scan oracle elsewhere).
      * active concrete mesh with experts dividing the model axis →
        shard_map wrapper: experts stay on the model axis (expert
        parallelism) — each device runs the grouped fused grid over its
        resident E/model compressed planes and the output stays
        expert-sharded for the caller's combine scatter.  Plane gathers
        move compressed bytes, never dense experts (§Perf D1 economics).
    Fallback (probe 'grouped_unfused'): linear-layout planes, expert
    counts that don't divide the model axis, abstract meshes, and
    ``impl='unfused'`` — materialize the dense expert stack, then einsum
    (the benchmark baseline, and the only path that pays E·N·K dense
    bytes).
    """
    impl = _resolve_unfused(impl)
    # 'materialize' is the same dense-stack einsum as 'unfused' here (the
    # fallback already decodes pure-jnp), probed separately.
    unfused = impl in ("unfused", "materialize")
    tile_n = getattr(packed, "tile_n", 0)
    e = xe.shape[0]
    if (not unfused and tile_n and lut is not None
            and packed.codes.ndim == 3):
        axis_sizes, mesh, ndev = _mesh_state()
        if ndev <= 1:
            DISPATCH_COUNTS["grouped_fused"] += 1
            return grouped_fused_local(xe, packed, lut, out_dtype=out_dtype,
                                       impl=impl)
        msize = axis_sizes.get("model", 1)
        if _is_concrete_mesh(mesh) and msize > 1 and e % msize == 0:
            DISPATCH_COUNTS["grouped_fused_shard_map"] += 1
            return _grouped_fused_sharded(xe, packed, lut,
                                          out_dtype=out_dtype, impl=impl,
                                          mesh=mesh)
    DISPATCH_COUNTS["grouped_materialize" if impl == "materialize"
                    else "grouped_unfused"] += 1
    assert lut is not None, \
        "grouped_decode_dequant_matmul: compressed stacks need the decode LUT"
    w = packed.materialize(lut, xe.dtype)             # (E, N, K) dense
    return jnp.einsum("emk,enk->emn", xe, w).astype(out_dtype)


def _grouped_fused_sharded(xe, packed, lut, *, out_dtype, impl: Impl, mesh):
    """shard_map-wrapped grouped megakernel: expert-parallel fused MoE.

    Experts split on the model axis for every plane and for the gathered
    token blocks; each device launches the grouped fused grid over its
    E/model resident compressed planes.  No reduction — the output stays
    expert-sharded on model, exactly the layout the MoE combine scatter
    constrains to (see ``layers.apply_moe``).
    """
    import dataclasses

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def local_fn(xl, codes, lits, nlit, lutl, scale, zero):
        loc = dataclasses.replace(packed, codes=codes, literals=lits,
                                  nlit=nlit, scale=scale, zero=zero)
        return grouped_fused_local(xl, loc, lutl, out_dtype=out_dtype,
                                   impl=impl)

    espec = P("model", None, None)
    y = shard_map(
        local_fn, mesh=mesh,
        in_specs=(espec, espec, P("model", None, None, None),
                  P("model", None), P(None, None), espec, espec),
        out_specs=espec,
        check_rep=False,
    )(xe, packed.codes, packed.literals, packed.nlit, lut,
      packed.scale, packed.zero)
    return y
