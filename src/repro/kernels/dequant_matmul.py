"""Fused W8A16 dequant × matmul Pallas TPU kernel.

The paper's inference path dequantizes a layer then matmuls; on TPU the
fusion is the perf win: int8 weights stream HBM→VMEM (half the bytes of
bf16) and dequantization happens on the fly per VMEM tile, so the MXU never
waits on a dense bf16 weight materialization.

Math trick (beyond-paper, exact): with per-output-channel affine
``w = (q - z)·s``,

    y[m,n] = Σ_k x[m,k]·w[n,k]
           = s[n]·( Σ_k x[m,k]·q[n,k]  −  z[n]·Σ_k x[m,k] )

so the hot loop is a pure int8-as-bf16 MXU matmul (q ≤ 255 is exact in
bf16), plus one running row-sum of x; the affine epilogue applies once per
output tile.  No per-element dequant multiply inside the K loop at all.

Grid: (M/bm, N/bn, K/bk), K innermost; accumulators live in VMEM scratch.

This kernel serves mode='quant' (dense uint8 weights) and the legacy
two-step compressed path.  For mode='compressed' the serving hot path is
``fused_decode_matmul.py``, which runs the SAME grid and affine-epilogue
math but decodes each (bn, bk) weight tile from its compressed blocks
inside the kernel — possible because ``core.blocked_codec`` lays blocks
out tile-major, one whole number of blocks per (tile_n, tile_k) tile.
Keep the two epilogues in sync: both compute y = s·(Σ x·q − z·Σ x) with
q exact in bf16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512


def _kernel(x_ref, wq_ref, scale_ref, zero_ref, o_ref, acc_ref, sumx_ref):
    k_idx = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        sumx_ref[...] = jnp.zeros_like(sumx_ref)

    x = x_ref[...].astype(jnp.bfloat16)                  # (bm, bk)
    q = wq_ref[...].astype(jnp.bfloat16)                 # (bn, bk) exact ≤255
    acc_ref[...] += jax.lax.dot_general(
        x, q, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # (bm, bn)
    sumx_ref[...] += jnp.sum(x.astype(jnp.float32), axis=1, keepdims=True)

    @pl.when(k_idx == nk - 1)
    def _epilogue():
        s = scale_ref[...].reshape(1, -1)                # (1, bn)
        z = zero_ref[...].reshape(1, -1)                 # (1, bn)
        o_ref[...] = (s * (acc_ref[...] - sumx_ref[...] * z)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret"))
def dequant_matmul(x: jax.Array, wq: jax.Array, scale: jax.Array,
                   zero: jax.Array, *, bm: int = DEFAULT_BM,
                   bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                   out_dtype=jnp.float32, interpret: bool = False):
    """y = x @ dequant(wq).T  — see ref.dequant_matmul for semantics.

    x: (M, K) float; wq: (N, K) uint8; scale/zero: (N, 1) f32.
    Shapes must tile evenly by (bm, bn, bk); ``ops.py`` pads otherwise.
    """
    m, kdim = x.shape
    n, k2 = wq.shape
    assert kdim == k2, (x.shape, wq.shape)
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, \
        (m, n, kdim, bm, bn, bk)

    grid = (m // bm, n // bn, kdim // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, 1), lambda i, j, k: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, 1), jnp.float32)],
        interpret=interpret,
    )(x, wq, scale, zero)
