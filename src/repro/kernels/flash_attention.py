"""Block-wise online-softmax attention (FlashAttention) Pallas TPU kernel.

Used by the prefill path where attention is the compute hot-spot
(32k-token prefill is quadratic).  Supports causal masking, GQA (kv-head
broadcast happens outside via head indexing in the BlockSpec index_map, so
kv blocks are *not* materialized per q-head), and a query-position offset
for chunked prefill.

Grid: (B·Hq, Tq/bq, Tk/bk) with k innermost; running (max, denom, acc)
scratch in VMEM; causal blocks that are fully masked are skipped by the
index structure (acc untouched → cheap @pl.when guard).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            sm_scale: float, causal: bool, q_offset: int, bq: int, bk: int):
    kb = pl.program_id(2)
    nkb = pl.num_programs(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        # Lowest query position in this q block vs lowest key position:
        # block fully masked iff highest key pos > highest query pos AND
        # lowest key pos > ... — keep simple: skip when first key index
        # exceeds the last query position.
        run = (kb * bk) <= (q_offset + (qb + 1) * bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                 # (bq, d)
        k = k_ref[0].astype(jnp.float32)                 # (bk, d)
        v = v_ref[0].astype(jnp.float32)                 # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                  # (bq, bk)
        if causal:
            qpos = q_offset + qb * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            kpos = kb * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]                               # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                            # (bq, bk)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == nkb - 1)
    def _final():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "sm_scale", "q_offset",
                                             "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, sm_scale: float | None = None,
                    q_offset: int = 0, bq: int = DEFAULT_BQ,
                    bk: int = DEFAULT_BK, interpret: bool = False):
    """q: (B, Hq, Tq, D); k/v: (B, Hkv, Tk, D); returns (B, Hq, Tq, D).

    GQA: kv heads are indexed as ``h // (Hq // Hkv)`` in the BlockSpec, so
    the kernel reads the shared kv block without materializing repeats.
    """
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    dv = v.shape[-1]
    assert hq % hkv == 0
    rep = hq // hkv
    sm = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    bq_ = min(bq, tq)
    bk_ = min(bk, tk)
    assert tq % bq_ == 0 and tk % bk_ == 0, (tq, tk, bq_, bk_)

    qr = q.reshape(b * hq, tq, d)
    kr = k.reshape(b * hkv, tk, d)
    vr = v.reshape(b * hkv, tk, dv)

    grid = (b * hq, tq // bq_, tk // bk_)
    kern = functools.partial(_kernel, sm_scale=sm, causal=causal,
                             q_offset=q_offset, bq=bq_, bk=bk_)

    def kv_head(h):  # flat q index -> flat kv index
        bi = h // hq
        hi = (h % hq) // rep
        return bi * hkv + hi

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk_, d), lambda h, i, j: (kv_head(h), j, 0)),
            pl.BlockSpec((1, bk_, dv), lambda h, i, j: (kv_head(h), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, dv), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, tq, dv), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq_, 1), jnp.float32),
                        pltpu.VMEM((bq_, 1), jnp.float32),
                        pltpu.VMEM((bq_, dv), jnp.float32)],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, tq, dv)
