"""Blocked dictionary-decode Pallas TPU kernel.

The paper decompresses "layer by layer" on CPU; the TPU-native version
decodes *per VMEM tile* so decompression overlaps the surrounding matmuls
(DESIGN.md §2).  The decode LUT stays resident in VMEM for every grid step
(≤ 64k codes × 4 B = 256 KiB), codes/literals stream through per block-chunk.

One grid step decodes ``chunk`` blocks: a LUT row-gather for dictionary
slots, plus a rank-gather (in-block cumsum over escape flags) for literal
slots — both fully vectorized; no serial stream walk remains.

Mosaic note: the row-gathers lower to ``dynamic_gather`` on the sublane
axis; on very old toolchains without gather support ``ops.py`` falls back to
the jnp oracle (same math, XLA gathers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.codec import ESCAPE

DEFAULT_CHUNK = 8


def _kernel(codes_ref, lit_ref, lut_ref, o_ref):
    codes = codes_ref[...].astype(jnp.int32)            # (cb, slots)
    is_esc = codes == ESCAPE
    safe = jnp.where(is_esc, 0, codes)
    from_dict = jnp.take(lut_ref[...], safe, axis=0)    # (cb, slots, S)
    rank = jnp.clip(jnp.cumsum(is_esc.astype(jnp.int32), axis=1) - 1,
                    0, lit_ref.shape[1] - 1)            # (cb, slots)
    lit = lit_ref[...]                                  # (cb, cap, S)
    from_lit = jnp.take_along_axis(
        lit, rank[:, :, None].astype(jnp.int32), axis=1)  # (cb, slots, S)
    out = jnp.where(is_esc[:, :, None], from_lit, from_dict)
    o_ref[...] = out.reshape(o_ref.shape)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def dict_decode(codes: jax.Array, literals: jax.Array, nlit: jax.Array,
                lut: jax.Array, *, chunk: int = DEFAULT_CHUNK,
                interpret: bool = False) -> jax.Array:
    """Decode (nb, slots) uint16 codes → (nb, slots·S) uint8 weights.

    ``nlit`` is carried for format completeness (the rank-gather clips past
    it harmlessly: rank rows beyond nlit are never selected because their
    slots are non-escape).
    """
    nb, slots = codes.shape
    cap, s = literals.shape[1], literals.shape[2]
    chunk = min(chunk, nb)
    assert nb % chunk == 0, (nb, chunk)
    grid = (nb // chunk,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk, slots), lambda b: (b, 0)),
            pl.BlockSpec((chunk, cap, s), lambda b: (b, 0, 0)),
            pl.BlockSpec(lut.shape, lambda b: (0, 0)),   # LUT resident
        ],
        out_specs=pl.BlockSpec((chunk, slots * s), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, slots * s), jnp.uint8),
        interpret=interpret,
    )(codes, literals, lut)
