"""Pallas TPU kernels for Tiny-QMoE hot spots (+ jnp oracles in ref.py).

  fused_decode_matmul — decode→dequant→matmul megakernel (serving hot path;
                        compressed blocks decode per tile inside the MXU
                        loop, the dense weight never touches HBM)
  dequant_matmul      — fused W8A16 dequant × matmul (quant mode / fallback)
  dict_decode         — blocked dictionary decompression in VMEM
  flash_attention     — block-wise online-softmax attention (prefill)
Use via ``repro.kernels.ops`` which handles padding + backend dispatch.
"""
from . import ops, ref
from .ops import (DEFAULT_LADDER, FUSED_RUNG, Impl, dequant_matmul,
                  dict_decode, flash_attention, decode_dequant_matmul)

__all__ = ["ops", "ref", "dequant_matmul", "dict_decode", "flash_attention",
           "decode_dequant_matmul", "Impl", "FUSED_RUNG", "DEFAULT_LADDER"]
