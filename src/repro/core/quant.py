"""Quantization core — the paper's §3 quantizer, generalized.

The paper's ``Quantizer`` (Listing 1) computes a single (scale, zero) pair per
tensor from the min/max range and rounds onto ``2**bits`` levels.  We keep
that exact algorithm as ``granularity='per_tensor'`` (the paper-faithful
path) and add per-channel / per-group granularity, symmetric mode, and a
ternary mode matching QMoE's {w_min, 0, w_max} scheme (used by the paper's
ablation that showed ternary destroys small models).

Everything is pure JAX and jit-safe; integer payloads are what the codec
(``repro.core.codec`` / ``blocked_codec``) consumes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

Granularity = Literal["per_tensor", "per_channel", "per_group"]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static quantization configuration.

    bits=1.5 selects ternary (QMoE-style) quantization, matching the paper's
    ``configure(1.5)`` convention.
    """

    bits: float = 8
    granularity: Granularity = "per_channel"
    group_size: int = 128          # only for per_group
    symmetric: bool = False        # paper's naive scheme is asymmetric
    channel_axis: int = 0          # rows of a (out, in) weight matrix

    @property
    def is_ternary(self) -> bool:
        return self.bits == 1.5

    @property
    def maxq(self) -> int:
        if self.is_ternary:
            return -1  # paper's sentinel
        return int(2 ** int(self.bits) - 1)

    @property
    def storage_dtype(self):
        if self.is_ternary or self.bits <= 8:
            return jnp.uint8
        return jnp.uint16


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Integer payload + affine params.  ``dequant`` restores the float view.

    values: integer codes, same shape as the original tensor.
    scale/zero: broadcastable against ``values`` along the quantization axes.
    """

    values: jax.Array          # uint8/uint16 codes
    scale: jax.Array           # float32
    zero: jax.Array            # float32 (stored as float; integer-valued)
    shape: tuple               # original shape (static)
    dtype: jnp.dtype           # original dtype (static)
    bits: float                # static
    layout: tuple | None = None  # (granularity, axis, group_size, moved_shape)

    def tree_flatten(self):
        return ((self.values, self.scale, self.zero),
                (self.shape, self.dtype, self.bits, self.layout))

    @classmethod
    def tree_unflatten(cls, aux, children):
        values, scale, zero = children
        shape, dtype, bits, layout = aux
        return cls(values, scale, zero, shape, dtype, bits, layout)

    def dequant(self) -> jax.Array:
        x = (self.values.astype(jnp.float32) - self.zero) * self.scale
        return x.reshape(self.shape).astype(self.dtype)

    @property
    def nbytes_payload(self) -> int:
        itemsize = 1 if self.bits <= 8 else 2
        n = 1
        for s in self.values.shape:
            n *= s
        return n * itemsize


def _moveaxis_for_channel(x: jax.Array, axis: int):
    """Reshape (…,) tensor to (channels, -1) rows for per-channel params."""
    x2 = jnp.moveaxis(x, axis, 0)
    return x2.reshape(x2.shape[0], -1), x2.shape


def find_params(x: jax.Array, cfg: QuantConfig):
    """Paper's ``find_params``: scale=(max-min)/maxq, zero=round(-min/scale).

    Returns (scale, zero) shaped for the configured granularity, operating on
    the *flattened-rows* view used by :func:`quantize`.
    """
    if cfg.is_ternary:
        # Paper: scale=xmax, zero=xmin (thresholding quantizer).
        xmin = jnp.min(x)
        xmax = jnp.max(x)
        return xmax[None], xmin[None]

    if cfg.granularity == "per_tensor":
        xmin = jnp.min(x)
        xmax = jnp.max(x)
        xmin = jnp.minimum(xmin, 0.0)
        xmax = jnp.maximum(xmax, 0.0)
        if cfg.symmetric:
            m = jnp.maximum(jnp.abs(xmin), jnp.abs(xmax))
            xmin, xmax = -m, m
        scale = (xmax - xmin) / cfg.maxq
        scale = jnp.where(scale <= 0, 1.0, scale)
        zero = jnp.round(-xmin / scale)
        return scale[None], zero[None]

    if cfg.granularity == "per_channel":
        rows, _ = _moveaxis_for_channel(x, cfg.channel_axis)
    else:  # per_group: group along the last axis of the 2D row view
        rows, _ = _moveaxis_for_channel(x, cfg.channel_axis)
        g = cfg.group_size
        pad = (-rows.shape[1]) % g
        if pad:
            rows = jnp.pad(rows, ((0, 0), (0, pad)))
        rows = rows.reshape(-1, g)

    xmin = jnp.minimum(rows.min(axis=1), 0.0)
    xmax = jnp.maximum(rows.max(axis=1), 0.0)
    if cfg.symmetric:
        m = jnp.maximum(jnp.abs(xmin), jnp.abs(xmax))
        xmin, xmax = -m, m
    scale = (xmax - xmin) / cfg.maxq
    scale = jnp.where(scale <= 0, 1.0, scale)
    zero = jnp.round(-xmin / scale)
    return scale[:, None], zero[:, None]


def quantize(x: jax.Array, cfg: QuantConfig) -> QuantizedTensor:
    """Quantize a float tensor. Paper Listing 1, generalized.

    The returned integer payload is laid out as the (channels, -1) /
    (groups, group_size) row view; ``dequant`` restores the original layout.
    """
    orig_shape, orig_dtype = x.shape, x.dtype
    xf = x.astype(jnp.float32)

    if cfg.is_ternary:
        scale, zero = find_params(xf, cfg)  # scale=xmax, zero=xmin
        hi = (xf > scale / 2).astype(jnp.uint8)          # -> xmax, code 2
        lo = (xf < zero / 2).astype(jnp.uint8)           # -> xmin, code 1
        codes = hi * 2 + lo                               # 0,1,2
        # Represent via affine-ish storage: dequant handled specially below.
        return TernaryTensor(codes, scale, zero, orig_shape, orig_dtype)

    if cfg.granularity == "per_tensor":
        scale, zero = find_params(xf, cfg)
        q = jnp.clip(jnp.round(xf.reshape(-1) / scale) + zero, 0, cfg.maxq)
        values = q.astype(cfg.storage_dtype)
        return QuantizedTensor(values, scale, zero, orig_shape, orig_dtype, cfg.bits)

    rows, moved_shape = _moveaxis_for_channel(xf, cfg.channel_axis)
    if cfg.granularity == "per_group":
        g = cfg.group_size
        pad = (-rows.shape[1]) % g
        if pad:
            rows = jnp.pad(rows, ((0, 0), (0, pad)))
        rows = rows.reshape(-1, g)
    scale, zero = find_params(xf, cfg)
    q = jnp.clip(jnp.round(rows / scale) + zero, 0, cfg.maxq)
    values = q.astype(cfg.storage_dtype)
    layout = (cfg.granularity, cfg.channel_axis, cfg.group_size, moved_shape)
    return QuantizedTensor(values, scale, zero, orig_shape, orig_dtype,
                           cfg.bits, layout)


def dequantize(qt: "QuantizedTensor") -> jax.Array:
    """Inverse of :func:`quantize` for any granularity."""
    if isinstance(qt, TernaryTensor):
        return qt.dequant()
    layout = qt.layout
    x = (qt.values.astype(jnp.float32) - qt.zero) * qt.scale
    if layout is None:  # per-tensor
        return x.reshape(qt.shape).astype(qt.dtype)
    granularity, axis, group_size, moved_shape = layout
    if granularity == "per_group":
        x = x.reshape(moved_shape[0], -1)
        n_inner = 1
        for s in moved_shape[1:]:
            n_inner *= s
        x = x[:, :n_inner]
    x = x.reshape(moved_shape)
    x = jnp.moveaxis(x, 0, axis)
    return x.astype(qt.dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TernaryTensor:
    """QMoE-style ternary codes {0:zero, 1:w_min, 2:w_max}."""

    codes: jax.Array
    w_max: jax.Array
    w_min: jax.Array
    shape: tuple
    dtype: jnp.dtype

    def tree_flatten(self):
        return (self.codes, self.w_max, self.w_min), (self.shape, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, w_max, w_min = children
        return cls(codes, w_max, w_min, *aux)

    def dequant(self) -> jax.Array:
        x = jnp.where(self.codes == 2, self.w_max,
                      jnp.where(self.codes == 1, self.w_min, 0.0))
        return x.reshape(self.shape).astype(self.dtype)


# ---------------------------------------------------------------------------
# Convenience jit'd round-trips used by tests / benchmarks.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",))
def fake_quant(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """quantize→dequantize in one jit (QAT-style straight-through value)."""
    return dequantize(quantize(x, cfg))


def quantization_error(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Mean squared quantization error — used by tests and the bit-width
    ablation benchmark reproducing the paper's ternary/2/4/6/8-bit sweep."""
    return jnp.mean((x - fake_quant(x, cfg)) ** 2)
