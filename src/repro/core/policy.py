"""Compression policy — which tensors carry Tiny-QMoE compression.

The paper quantizes "all parameter weights with 'weight' in name"; in
practice (and in QMoE[1]) accuracy-critical small tensors are excluded.
Policy rules (DESIGN.md §Arch-applicability):

  * 2-D matmul weights >= min_weight_size  -> quantize + compress
  * embeddings / lm_head                   -> configurable (default: quant
    only — gather from int8 is fine, but dictionary decode of a row-gathered
    table is wasteful)
  * norms, biases, routers, SSM recurrence params (A_log, dt, conv, D),
    rotary tables                          -> keep bf16
"""
from __future__ import annotations

import dataclasses
import re

EXCLUDE_PATTERNS = (
    r"norm", r"bias", r"router", r"gate_logit", r"a_log", r"dt", r"conv",
    r"\bD\b", r"rope", r"rotary", r"scale", r"zero", r"embed_pos",
    # per-layer 1-D params that look 2-D once layer-stacked (L, dim):
    r"\bb[qkv]\b", r"d_skip",
)


@dataclasses.dataclass(frozen=True)
class CompressionPolicy:
    mode: str = "compressed"          # dense | quant | compressed
    min_weight_size: int = 65536      # below this, keep dense
    compress_embeddings: bool = False # embeddings: quant-only by default
    bits: float = 8
    block_weights: int = 4096
    exclude_extra: tuple = ()
    # 2D-TP storage (§Perf D2): split each compressed weight into this many
    # column tiles (== data-axis size); 0/1 = untiled FSDP planes.
    tiles: int = 0

    def excluded(self, name: str) -> bool:
        pats = EXCLUDE_PATTERNS + tuple(self.exclude_extra)
        low = name.lower()
        return any(re.search(p, low) for p in pats)

    def action(self, name: str, shape: tuple) -> str:
        """-> 'dense' | 'quant' | 'compressed' for one named tensor."""
        if self.mode == "dense":
            return "dense"
        n = 1
        for s in shape:
            n *= s
        if len(shape) < 2 or n < self.min_weight_size or self.excluded(name):
            return "dense"
        if "embed" in name.lower() or "lm_head" in name.lower():
            if self.mode == "compressed" and self.compress_embeddings:
                return "compressed"
            return "quant"
        return self.mode
