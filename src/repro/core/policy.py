"""Compression policy — which tensors carry Tiny-QMoE compression.

The paper quantizes "all parameter weights with 'weight' in name"; in
practice (and in QMoE[1]) accuracy-critical small tensors are excluded.
Policy rules (DESIGN.md §Arch-applicability):

  * 2-D matmul weights >= min_weight_size  -> quantize + compress
  * embeddings / lm_head                   -> configurable (default: quant
    only — gather from int8 is fine, but dictionary decode of a row-gathered
    table is wasteful)
  * norms, biases, routers, SSM recurrence params (A_log, dt, conv, D),
    rotary tables                          -> keep bf16
"""
from __future__ import annotations

import dataclasses
import re

EXCLUDE_PATTERNS = (
    r"norm", r"bias", r"router", r"gate_logit", r"a_log", r"dt", r"conv",
    r"\bD\b", r"rope", r"rotary", r"scale", r"zero", r"embed_pos",
    # per-layer 1-D params that look 2-D once layer-stacked (L, dim):
    r"\bb[qkv]\b", r"d_skip",
)


@dataclasses.dataclass(frozen=True)
class CompressionPolicy:
    mode: str = "compressed"          # dense | quant | compressed
    min_weight_size: int = 65536      # below this, keep dense
    compress_embeddings: bool = False # embeddings: quant-only by default
    bits: float = 8
    block_weights: int = 4096
    exclude_extra: tuple = ()
    # 2D-TP storage (§Perf D2): split each compressed weight into this many
    # column tiles (== data-axis size); 0/1 = untiled FSDP planes.
    tiles: int = 0

    def excluded(self, name: str) -> bool:
        pats = EXCLUDE_PATTERNS + tuple(self.exclude_extra)
        low = name.lower()
        return any(re.search(p, low) for p in pats)

    def action(self, name: str, shape: tuple) -> str:
        """-> 'dense' | 'quant' | 'compressed' for one named tensor."""
        if self.mode == "dense":
            return "dense"
        n = 1
        for s in shape:
            n *= s
        if len(shape) < 2 or n < self.min_weight_size or self.excluded(name):
            return "dense"
        if "embed" in name.lower() or "lm_head" in name.lower():
            if self.mode == "compressed" and self.compress_embeddings:
                return "compressed"
            return "quant"
        return self.mode


@dataclasses.dataclass(frozen=True)
class DeviceBudget:
    """HBM budget split for tiered-residency serving (bytes throughout).

    The paper's deployment regime is a 4–8 GB unified-memory edge device:
    the compressed model does not have to fit — only the *resident* slice
    does.  ``fits`` says whether everything that must stay on-device
    (non-expert weights + KV pages + activation headroom) leaves any room
    at all; ``expert_cache_bytes`` is what's left over for the per-layer
    expert cache, and ``cache_experts_per_layer`` converts it at a given
    per-expert compressed footprint.
    """
    budget_bytes: int
    resident_bytes: int        # non-expert weights pinned on device
    kv_bytes: int              # KV pool / paged cache
    act_bytes: int             # activation + workspace headroom
    expert_bytes: int          # total compressed expert planes (all layers)

    @property
    def reserved_bytes(self) -> int:
        return self.resident_bytes + self.kv_bytes + self.act_bytes

    @property
    def expert_cache_bytes(self) -> int:
        """Bytes left for the HBM expert cache (may be 0)."""
        return max(0, self.budget_bytes - self.reserved_bytes)

    @property
    def fits(self) -> bool:
        """True when the reserved set + at least one cached expert's worth
        of planes fits the budget (expert_bytes == 0 → just the reserve)."""
        return self.expert_cache_bytes > 0 or self.expert_bytes == 0

    @property
    def fully_resident(self) -> bool:
        """True when every compressed expert fits alongside the reserve —
        tiering would only add bookkeeping."""
        return self.expert_cache_bytes >= self.expert_bytes

    def cache_experts_per_layer(self, n_layers: int,
                                bytes_per_expert: int) -> int:
        """Experts per MoE layer the leftover budget can cache (>= 0)."""
        if n_layers <= 0 or bytes_per_expert <= 0:
            return 0
        return int(self.expert_cache_bytes // (n_layers * bytes_per_expert))

    # -- runtime budget adaptation (serve/governor.py) ------------------
    def resplit(self, budget_bytes: int, *,
                kv_bytes: int | None = None) -> "DeviceBudget":
        """Re-split under a *moved* runtime budget (the 4–8 GB unified-
        memory regime: the OS can reclaim hundreds of MiB mid-decode).
        The class stays frozen — a re-split is a new value, so every
        holder of the old split keeps a consistent snapshot; the
        ``MemoryGovernor`` swaps its reference at a step fence.  The
        resident and activation reserves are not elastic; ``kv_bytes``
        may shrink/regrow with the paged pool."""
        return dataclasses.replace(
            self, budget_bytes=int(budget_bytes),
            kv_bytes=self.kv_bytes if kv_bytes is None else int(kv_bytes))

    def min_viable(self, *, kv_floor_bytes: int = 0,
                   expert_floor_bytes: int = 0) -> int:
        """The smallest budget the engine can run under at all: the
        inelastic reserve (resident weights + activation workspace) plus
        the floors of the two elastic tiers — one decode slot's KV pages
        and one cached expert per MoE layer.  Below this the reclaim
        ladder cannot help; the governor clamps here and *refuses new
        work* instead of pretending to fit (the overshoot is surfaced,
        never hidden)."""
        return int(self.resident_bytes + self.act_bytes
                   + kv_floor_bytes + expert_floor_bytes)

    def summary(self, expert_cache_used: int | None = None) -> str:
        mib = 2.0 ** 20
        s = (f"device budget {self.budget_bytes / mib:.0f} MiB: "
             f"resident {self.resident_bytes / mib:.1f} + "
             f"kv {self.kv_bytes / mib:.1f} + "
             f"act {self.act_bytes / mib:.1f} MiB reserved -> "
             f"{self.expert_cache_bytes / mib:.1f} MiB expert cache "
             f"({'fully resident' if self.fully_resident else 'tiered'}"
             f"; experts total {self.expert_bytes / mib:.1f} MiB)")
        if expert_cache_used is not None \
                and expert_cache_used > self.expert_cache_bytes:
            over = expert_cache_used - self.expert_cache_bytes
            s += (f" — OVERSHOOT: cache holds "
                  f"{expert_cache_used / mib:.1f} MiB, "
                  f"{over / mib:.1f} MiB over the granted budget")
        return s


def device_budget(budget_bytes: int, *, expert_bytes: int,
                  resident_bytes: int = 0, kv_bytes: int = 0,
                  act_bytes: int = 0) -> DeviceBudget:
    """Split an HBM byte budget across what must vs may live on device.

    Used by ``launch/serve.py`` to default ``--expert-cache-mib`` and by
    dry-run prints; see ``docs/residency.md`` for the 4–8 GB budget math.
    """
    return DeviceBudget(budget_bytes=int(budget_bytes),
                        resident_bytes=int(resident_bytes),
                        kv_bytes=int(kv_bytes), act_bytes=int(act_bytes),
                        expert_bytes=int(expert_bytes))
