"""GPTQ — data-dependent post-training quantization (paper §3, ref [3]).

The paper applies GPTQ on top of its naive quantizer to recover accuracy.
This is a pure-JAX reimplementation of the GPTQ solver:

  * accumulate the layer Hessian  H = 2 Σ x xᵀ  over calibration batches,
  * dampen (H += λ·mean(diag)·I) and Cholesky-factorize,
  * walk columns in blocks; quantize each column, propagate the weighted
    error to the not-yet-quantized columns via the inverse-Hessian row.

The column walk is a ``lax.fori_loop`` so the whole solver jits. Weights are
quantized *row-wise independently* (per-channel grid), matching the GPTQ
reference implementation's ``perchannel=True`` mode and our default
``QuantConfig(granularity='per_channel')``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .quant import QuantConfig, QuantizedTensor


def accumulate_hessian(h: jax.Array, x: jax.Array) -> jax.Array:
    """Streaming Hessian update.  x: (..., in_features) activations."""
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    return h + 2.0 * (x2.T @ x2)


def init_hessian(in_features: int) -> jax.Array:
    return jnp.zeros((in_features, in_features), jnp.float32)


def _find_grid(w: jax.Array, maxq: int, symmetric: bool):
    """Per-row (scale, zero) over the full weight matrix (GPTQ keeps the grid
    fixed while the values move)."""
    xmin = jnp.minimum(w.min(axis=1), 0.0)
    xmax = jnp.maximum(w.max(axis=1), 0.0)
    if symmetric:
        m = jnp.maximum(jnp.abs(xmin), jnp.abs(xmax))
        xmin, xmax = -m, m
    scale = (xmax - xmin) / maxq
    scale = jnp.where(scale <= 0, 1.0, scale)
    zero = jnp.round(-xmin / scale)
    return scale[:, None], zero[:, None]


def _quant_col(col: jax.Array, scale: jax.Array, zero: jax.Array, maxq: int):
    q = jnp.clip(jnp.round(col / scale) + zero, 0, maxq)
    return q, scale * (q - zero)


@partial(jax.jit, static_argnames=("cfg", "percdamp"))
def gptq_quantize(w: jax.Array, hessian: jax.Array, cfg: QuantConfig,
                  percdamp: float = 0.01) -> QuantizedTensor:
    """Run the GPTQ solver on one weight matrix.

    Args:
      w: (out_features, in_features) float weight.
      hessian: (in, in) accumulated via :func:`accumulate_hessian`.
      cfg: quantization config; bits and symmetric honored; the grid is
        per-channel (rows) as in reference GPTQ.
    Returns:
      QuantizedTensor whose payload layout matches
      ``QuantConfig(granularity='per_channel')`` (rows = out_features).
    """
    out_f, in_f = w.shape
    maxq = cfg.maxq
    wf = w.astype(jnp.float32)

    # --- dead-column handling + damping ------------------------------------
    diag = jnp.diag(hessian)
    dead = diag == 0.0
    h = hessian + jnp.diag(jnp.where(dead, 1.0, 0.0))
    wf = wf * (~dead)[None, :]  # zero dead columns (no calibration signal)

    damp = percdamp * jnp.mean(jnp.diag(h))
    h = h + damp * jnp.eye(in_f, dtype=jnp.float32)

    # GPTQ walks the *upper* Cholesky factor U of Hinv with Hinv = Uᵀ U
    # (torch.cholesky(·, upper=True) semantics).  chol() returns lower L
    # with Hinv = L Lᵀ, and U = Lᵀ satisfies Uᵀ U = L Lᵀ = Hinv.
    hinv = jnp.linalg.inv(h)
    u = jnp.linalg.cholesky(hinv).T  # upper-triangular, Hinv = uᵀ u

    scale, zero = _find_grid(wf, maxq, cfg.symmetric)

    def body(i, carry):
        wcur, qvals = carry
        col = jax.lax.dynamic_slice_in_dim(wcur, i, 1, axis=1)[:, 0]
        d = u[i, i]
        q, dq = _quant_col(col, scale[:, 0], zero[:, 0], maxq)
        err = (col - dq) / d
        # Propagate error to remaining columns: w[:, j>i] -= err ⊗ u[i, j>i].
        row = u[i]                        # (in_f,)
        mask = (jnp.arange(in_f) > i).astype(jnp.float32)
        wnew = wcur - err[:, None] * (row * mask)[None, :]
        # Freeze column i at its dequantized value.
        wnew = jax.lax.dynamic_update_slice_in_dim(
            wnew, dq[:, None], i, axis=1)
        qvals = jax.lax.dynamic_update_slice_in_dim(
            qvals, q.astype(jnp.float32)[:, None], i, axis=1)
        return wnew, qvals

    qvals0 = jnp.zeros_like(wf)
    _, qvals = jax.lax.fori_loop(0, in_f, body, (wf, qvals0))

    values = qvals.astype(cfg.storage_dtype)
    layout = ("per_channel", 0, cfg.group_size, (out_f, in_f))
    return QuantizedTensor(values, scale, zero, w.shape, w.dtype,
                           cfg.bits, layout)


def gptq_layer_error(w: jax.Array, qt: QuantizedTensor,
                     hessian: jax.Array) -> jax.Array:
    """Proxy objective GPTQ minimizes: tr((W-Ŵ) H (W-Ŵ)ᵀ)."""
    from .quant import dequantize
    dw = (w.astype(jnp.float32) - dequantize(qt).astype(jnp.float32))
    return jnp.trace(dw @ hessian @ dw.T)


def calibrate_and_quantize(w: jax.Array, xs: list[jax.Array],
                           cfg: QuantConfig, percdamp: float = 0.01):
    """Convenience: stream calibration activations then solve."""
    h = init_hessian(w.shape[1])
    for x in xs:
        h = accumulate_hessian(h, x)
    return gptq_quantize(w, h, cfg, percdamp)
