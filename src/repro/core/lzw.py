"""LZW baseline — the dictionary-compression family the paper cites (§2.2).

The paper describes its schema as "LZW-based"; its actual format (Listings
2–4) is a static-dictionary variant.  We implement true LZW here as the
baseline benchmark the paper's §2.2 narrative implies, so the compression
table in ``benchmarks/compression.py`` can report paper-codec vs LZW vs
blocked-codec side by side.

Host-side, operates on uint8 arrays, 16-bit code cap (dictionary frozen when
full — standard practice for fixed-width LZW).
"""
from __future__ import annotations

import numpy as np

MAX_CODE = 0xFFFF  # 16-bit codes


def lzw_encode(data: np.ndarray) -> np.ndarray:
    """Classic LZW over bytes → uint16 code stream."""
    flat = np.ascontiguousarray(data).reshape(-1).astype(np.uint8).tobytes()
    table: dict[bytes, int] = {bytes([i]): i for i in range(256)}
    next_code = 256
    out: list[int] = []
    w = b""
    for ch in flat:
        c = bytes([ch])
        wc = w + c
        if wc in table:
            w = wc
        else:
            out.append(table[w])
            if next_code <= MAX_CODE:
                table[wc] = next_code
                next_code += 1
            w = c
    if w:
        out.append(table[w])
    return np.asarray(out, dtype=np.uint16)


def lzw_decode(codes: np.ndarray, orig_len: int) -> np.ndarray:
    """Inverse of :func:`lzw_encode`."""
    table: dict[int, bytes] = {i: bytes([i]) for i in range(256)}
    next_code = 256
    stream = codes.tolist()
    if not stream:
        return np.zeros(0, np.uint8)
    w = table[stream[0]]
    out = bytearray(w)
    for code in stream[1:]:
        if code in table:
            entry = table[code]
        elif code == next_code:  # KwKwK case
            entry = w + w[:1]
        else:
            raise ValueError(f"bad LZW code {code}")
        out.extend(entry)
        if next_code <= MAX_CODE:
            table[next_code] = w + entry[:1]
            next_code += 1
        w = entry
    return np.frombuffer(bytes(out[:orig_len]), dtype=np.uint8).copy()


def lzw_ratio(data: np.ndarray) -> float:
    """bytes-in / bytes-out for the 16-bit LZW stream."""
    enc = lzw_encode(data)
    return data.size / max(enc.nbytes, 1)
