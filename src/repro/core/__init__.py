"""Tiny-QMoE core: quantization, dictionary compression, packed params."""
from .quant import (QuantConfig, QuantizedTensor, TernaryTensor, quantize,
                    dequantize, fake_quant, quantization_error)
from .gptq import (gptq_quantize, accumulate_hessian, init_hessian,
                   calibrate_and_quantize, gptq_layer_error)
from .codec import (ESCAPE, find_frequent_sequences, compress_array,
                    decompress_array, compress_model_arrays,
                    decompress_model_arrays, compression_ratio,
                    CompressedStream)
from .blocked_codec import (BlockedCompressed, encode_blocked,
                            decode_blocked_jnp, build_lut, decode_to)
from .lzw import lzw_encode, lzw_decode, lzw_ratio
from .compressed import (QuantLinear, PackedLinear, quantize_linear,
                         pack_linear, planned_packed_specs,
                         planned_quant_specs, lut_spec)
from .policy import CompressionPolicy, DeviceBudget, device_budget
from .integrity import (IntegrityError, IntegrityReport, build_manifest,
                        check_invariants, verify_serve_state)

__all__ = [
    "QuantConfig", "QuantizedTensor", "TernaryTensor", "quantize",
    "dequantize", "fake_quant", "quantization_error",
    "gptq_quantize", "accumulate_hessian", "init_hessian",
    "calibrate_and_quantize", "gptq_layer_error",
    "ESCAPE", "find_frequent_sequences", "compress_array",
    "decompress_array", "compress_model_arrays", "decompress_model_arrays",
    "compression_ratio", "CompressedStream",
    "BlockedCompressed", "encode_blocked", "decode_blocked_jnp", "build_lut",
    "decode_to", "lzw_encode", "lzw_decode", "lzw_ratio",
    "QuantLinear", "PackedLinear", "quantize_linear", "pack_linear",
    "planned_packed_specs", "planned_quant_specs", "lut_spec",
    "CompressionPolicy",
    "DeviceBudget",
    "device_budget",
    "IntegrityError", "IntegrityReport", "build_manifest",
    "check_invariants", "verify_serve_state",
]
