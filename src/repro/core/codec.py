"""Paper-faithful dictionary codec (Listings 2–4) — host-side numpy.

This is the *reference/validation* codec: byte-exact reimplementation of the
paper's escape-stream format, used to reproduce Table 1's compression ratios
and the losslessness claim.  The TPU-parallel format lives in
``blocked_codec.py`` (see DESIGN.md §2 for why the stream layout changes).

Format (paper Listing 3):
  stream of uint16; a value < ESCAPE is a codeword for a ``sequence_length``
  run of uint8 quantized weights; ESCAPE (0xFFFF) is followed by
  ``sequence_length`` raw weights stored one-per-uint16.  A trailing
  ESCAPE + remainder handles lengths not divisible by sequence_length.
"""
from __future__ import annotations

import dataclasses
from collections import Counter

import numpy as np

ESCAPE = 0xFFFF
DEFAULT_SEQ_LEN = 4
MAX_TABLE = ESCAPE  # codewords 0..0xFFFE


def find_frequent_sequences(weights_list: list[np.ndarray],
                            sequence_length: int = DEFAULT_SEQ_LEN,
                            max_codes: int = MAX_TABLE,
                            min_count: int = 2,
                            sample_cap: int | None = 50_000_000) -> dict:
    """Paper Listing 2: frequency table over length-``sequence_length``
    subsequences of the flattened quantized weights.

    Returns {tuple(seq) -> codeword}, codewords dense in [0, n_codes).
    """
    counter: Counter = Counter()
    budget = sample_cap if sample_cap is not None else float("inf")
    for w in weights_list:
        flat = np.ascontiguousarray(w).reshape(-1).astype(np.uint8)
        n = (len(flat) // sequence_length) * sequence_length
        if n == 0:
            continue
        grams = flat[:n].reshape(-1, sequence_length)
        if len(grams) > budget:
            grams = grams[: int(budget)]
        budget -= len(grams)
        # view as void for fast unique
        u, c = np.unique(grams, axis=0, return_counts=True)
        for row, cnt in zip(u, c):
            counter[tuple(int(v) for v in row)] += int(cnt)
        if budget <= 0:
            break
    most = [(seq, cnt) for seq, cnt in counter.most_common(max_codes)
            if cnt >= min_count]
    return {seq: i for i, (seq, _) in enumerate(most)}


def compress_array(weights: np.ndarray, table: dict,
                   sequence_length: int = DEFAULT_SEQ_LEN) -> np.ndarray:
    """Paper Listing 3, vectorized but format-identical.

    Produces the exact uint16 stream the paper's serial loop produces.
    """
    flat = np.ascontiguousarray(weights).reshape(-1).astype(np.uint8)
    n_full = len(flat) // sequence_length
    head = flat[: n_full * sequence_length].reshape(-1, sequence_length)
    tail = flat[n_full * sequence_length:]

    # Vectorized lookup: pack grams to a single uint32 key.
    if sequence_length == 4:
        keys = head.astype(np.uint32)
        packed = (keys[:, 0] << 24) | (keys[:, 1] << 16) | (keys[:, 2] << 8) | keys[:, 3]
        lut = {}
        for seq, code in table.items():
            k = (seq[0] << 24) | (seq[1] << 16) | (seq[2] << 8) | seq[3]
            lut[k] = code
        codes = np.array([lut.get(int(k), -1) for k in packed], dtype=np.int64)
    else:
        codes = np.array([table.get(tuple(int(v) for v in row), -1)
                          for row in head], dtype=np.int64)

    out: list[int] = []
    hit = codes >= 0
    # Serial emission to match the paper's stream exactly (escape layout).
    for i in range(len(head)):
        if hit[i]:
            out.append(int(codes[i]))
        else:
            out.append(ESCAPE)
            out.extend(int(v) for v in head[i])
    if tail.size > 0:
        out.append(ESCAPE)
        out.extend(int(v) for v in tail)
    return np.asarray(out, dtype=np.uint16)


def decompress_array(stream: np.ndarray, table: dict, orig_len: int,
                     sequence_length: int = DEFAULT_SEQ_LEN) -> np.ndarray:
    """Paper Listing 4."""
    inv = {code: np.asarray(seq, dtype=np.uint8) for seq, code in table.items()}
    out = np.empty(orig_len + sequence_length, dtype=np.uint8)
    pos = 0
    i = 0
    n = len(stream)
    while i < n:
        cw = int(stream[i]); i += 1
        if cw == ESCAPE:
            remaining = min(sequence_length, orig_len - pos, n - i)
            out[pos:pos + remaining] = stream[i:i + remaining].astype(np.uint8)
            pos += remaining
            i += remaining
        else:
            seq = inv[cw]
            out[pos:pos + sequence_length] = seq
            pos += sequence_length
    return out[:orig_len]


@dataclasses.dataclass
class CompressedStream:
    """One tensor compressed in the paper's stream format."""

    stream: np.ndarray        # uint16
    orig_len: int
    shape: tuple
    sequence_length: int = DEFAULT_SEQ_LEN

    @property
    def nbytes(self) -> int:
        return int(self.stream.nbytes)


def compress_model_arrays(arrays: dict[str, np.ndarray],
                          sequence_length: int = DEFAULT_SEQ_LEN,
                          table: dict | None = None,
                          max_codes: int = MAX_TABLE):
    """Paper's ``compress_model`` over a {name: uint8 array} dict.

    Returns (table, {name: CompressedStream}).  One table for the whole
    model, as in the paper.
    """
    if table is None:
        table = find_frequent_sequences(list(arrays.values()),
                                        sequence_length, max_codes)
    out = {}
    for name, arr in arrays.items():
        stream = compress_array(arr, table, sequence_length)
        out[name] = CompressedStream(stream, arr.size, arr.shape,
                                     sequence_length)
    return table, out


def decompress_model_arrays(table: dict,
                            streams: dict[str, "CompressedStream"]):
    out = {}
    for name, cs in streams.items():
        flat = decompress_array(cs.stream, table, cs.orig_len,
                                cs.sequence_length)
        out[name] = flat.reshape(cs.shape)
    return out


def table_nbytes(table: dict, sequence_length: int = DEFAULT_SEQ_LEN) -> int:
    """Bytes to ship the decode LUT (counted against the compressed size,
    as the paper's on-disk format must include it)."""
    return len(table) * sequence_length


def compression_ratio(arrays: dict[str, np.ndarray],
                      streams: dict[str, CompressedStream],
                      table: dict,
                      original_bytes_per_weight: int = 2) -> dict:
    """Table-1-style accounting.

    original: fp16/bf16 model bytes; quantized: 1 byte/weight; compressed:
    escape-stream bytes + LUT.
    """
    n_weights = sum(a.size for a in arrays.values())
    original = n_weights * original_bytes_per_weight
    quantized = n_weights
    compressed = sum(s.nbytes for s in streams.values()) + table_nbytes(table)
    return {
        "n_weights": int(n_weights),
        "original_bytes": int(original),
        "quantized_bytes": int(quantized),
        "compressed_bytes": int(compressed),
        "ratio_vs_original": original / max(compressed, 1),
        "ratio_vs_quantized": quantized / max(compressed, 1),
    }
