"""Artifact integrity — checksummed serve params + device-side invariants.

Dictionary compression amplifies faults: one flipped bit in a PackedLinear
code plane mis-indexes the LUT and silently corrupts an entire decoded
tile — a failure mode dense checkpoints don't have.  A serving host with
flash-backed storage and no network to re-download weights must therefore
be able to *prove* the artifact it loaded is the artifact that was packed.

Two complementary layers (neither subsumes the other):

  * **Host-side manifest** (``build_manifest`` / ``verify_serve_state``):
    per-plane CRC32 digests over every compressed/quantized plane (codes,
    literals, nlit, scale, zero), the model-wide LUT and the dictionary
    table, recorded at pack time on ``ServeState.manifest``.  ``level=
    'full'`` re-hashes every byte (ground truth — catches *any* flip);
    ``level='fast'`` fully hashes small planes and strided-samples large
    ones (bounded time, probabilistic detection — the boot-time check).
    Corrupted leaves are *named* per plane and quarantined in the report,
    never silently decoded.
  * **Device-side invariants** (``check_invariants``): a cheap jittable
    structural check that can run on-accelerator before the first prefill
    — every code indexes inside the LUT (or is ESCAPE), every nlit fits
    the literal capacity, every scale/zero is finite.  Catches the
    corruption class that crashes or NaN-poisons a decode; a flip that
    lands *inside* the valid code range is invisible here and is exactly
    what the CRC layer exists for.

``serve.resilience.ResilientEngine`` runs both per its policy and refuses
to serve from a quarantined artifact (the integrity invariant documented
in ``serve/engine.py``).
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .codec import ESCAPE
from .compressed import PackedLinear, QuantLinear, TiledPackedLinear

# fast-level policy: planes at or below this are fully hashed even in
# 'fast' mode; larger planes hash a strided byte sample of about this size.
FAST_FULL_MAX = 1 << 18
_FAST_SAMPLE = 1 << 16

MANIFEST_VERSION = 1


class IntegrityError(RuntimeError):
    """Raised when a quarantined (corrupt) artifact would otherwise serve."""

    def __init__(self, report: "IntegrityReport"):
        self.report = report
        super().__init__("artifact integrity check failed: "
                         + "; ".join(f"{leaf}[{plane}]: {reason}"
                                     for leaf, plane, reason in report.corrupt))


@dataclasses.dataclass
class IntegrityReport:
    level: str
    ok: bool
    corrupt: list            # [(leaf, plane, reason)] — named, per plane
    checked: int             # planes compared
    bytes_hashed: int
    elapsed_s: float

    @property
    def quarantined(self) -> list:
        """Sorted unique leaf names that must not be decoded."""
        return sorted({leaf for leaf, _, _ in self.corrupt})

    def summary(self) -> str:
        if self.ok:
            return (f"verify[{self.level}]: ok — {self.checked} planes, "
                    f"{self.bytes_hashed / 2**20:.1f} MiB hashed in "
                    f"{self.elapsed_s * 1e3:.1f} ms")
        return (f"verify[{self.level}]: CORRUPT — "
                f"{len(self.corrupt)} plane(s) in "
                f"{len(self.quarantined)} leaf(s): "
                + "; ".join(f"{l}[{p}]: {r}" for l, p, r in self.corrupt))


def _u8_view(arr) -> np.ndarray:
    """Host byte view of any array leaf (contiguous, flat uint8)."""
    a = np.ascontiguousarray(np.asarray(jax.device_get(arr)))
    if a.size == 0:
        return np.zeros(0, np.uint8)
    return a.reshape(-1).view(np.uint8)


def _crc_full(u8: np.ndarray) -> int:
    return zlib.crc32(u8) & 0xFFFFFFFF


def _crc_fast(u8: np.ndarray) -> int:
    """Strided-sample digest for large planes (bounded hash time).

    Detects truncation/garbling with near certainty; a *single* bit flip
    is caught only if it lands on a sampled byte — use level='full' for
    ground truth.  Length is mixed in so same-sample truncations differ.
    """
    n = u8.size
    if n <= FAST_FULL_MAX:
        return _crc_full(u8)
    stride = max(1, n // _FAST_SAMPLE)
    sample = np.ascontiguousarray(u8[::stride])
    head = u8[:256]
    tail = np.ascontiguousarray(u8[-256:])
    c = zlib.crc32(n.to_bytes(8, "little"))
    for part in (head, sample, tail):
        c = zlib.crc32(part, c)
    return c & 0xFFFFFFFF


def _table_crc(table: Optional[dict]) -> Optional[int]:
    if table is None:
        return None
    c = 0
    for seq, code in sorted(table.items(), key=lambda kv: kv[1]):
        c = zlib.crc32(bytes(seq) + int(code).to_bytes(4, "little"), c)
    return c & 0xFFFFFFFF


def _iter_plane_leaves(params):
    """Yield (name, array) for every array leaf, plane-granular.

    PackedLinear/TiledPackedLinear/QuantLinear register their planes as
    keyed children, so ``tree_flatten_with_path`` already names each plane
    (``...['w_gate'].codes``) — the manifest keys on those full paths.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            yield jax.tree_util.keystr(path), leaf


def _plane_entry(arr) -> dict:
    u8 = _u8_view(arr)
    return {
        "shape": [int(s) for s in np.asarray(arr).shape],
        "dtype": str(np.asarray(arr).dtype),
        "nbytes": int(u8.size),
        "crc32": _crc_full(u8),
        "crc32_fast": _crc_fast(u8),
    }


def build_manifest(params: Any, lut=None, table: Optional[dict] = None) -> dict:
    """Per-plane integrity manifest of a served param tree (host side).

    JSON-serializable; stored on ``ServeState.manifest`` by
    ``serve.engine.build_serve_params``.
    """
    t0 = time.perf_counter()
    leaves = {}
    total = 0
    for name, arr in _iter_plane_leaves(params):
        entry = _plane_entry(arr)
        leaves[name] = entry
        total += entry["nbytes"]
    lut_entry = None
    if lut is not None:
        lut_entry = _plane_entry(lut)
        total += lut_entry["nbytes"]
    return {
        "version": MANIFEST_VERSION,
        "leaves": leaves,
        "lut": lut_entry,
        "table_crc32": _table_crc(table),
        "total_bytes": total,
        "build_s": time.perf_counter() - t0,
    }


def _check_plane(name: str, plane: str, arr, entry: dict, level: str,
                 corrupt: list) -> int:
    a = np.asarray(jax.device_get(arr))
    if list(a.shape) != entry["shape"]:
        corrupt.append((name, plane,
                        f"shape {list(a.shape)} != manifest {entry['shape']}"))
        return 0
    if str(a.dtype) != entry["dtype"]:
        corrupt.append((name, plane,
                        f"dtype {a.dtype} != manifest {entry['dtype']}"))
        return 0
    u8 = _u8_view(a)
    if level == "full":
        got, want, tag = _crc_full(u8), entry["crc32"], "crc32"
    else:
        got, want, tag = _crc_fast(u8), entry["crc32_fast"], "crc32_fast"
    if got != want:
        corrupt.append((name, plane,
                        f"{tag} {got:#010x} != manifest {want:#010x}"))
    return u8.size


def verify_serve_state(state, *, level: str = "full") -> IntegrityReport:
    """Re-hash a ServeState host-side against its pack-time manifest.

    ``level``: 'off' (no-op ok report), 'fast' (sampled digests, bounded
    time), 'full' (every byte — ground truth).  Every mismatching plane is
    named ``(leaf, plane, reason)`` in ``report.corrupt``; the union of
    leaves is ``report.quarantined``.
    """
    t0 = time.perf_counter()
    if level == "off":
        return IntegrityReport(level, True, [], 0, 0, 0.0)
    if level not in ("fast", "full"):
        raise ValueError(f"verify level {level!r} not in off|fast|full")
    manifest = getattr(state, "manifest", None)
    if not manifest:
        raise ValueError("ServeState carries no integrity manifest "
                         "(built with manifest=False?)")
    corrupt: list = []
    checked = 0
    hashed = 0
    seen = set()
    for name, arr in _iter_plane_leaves(state.params):
        seen.add(name)
        entry = manifest["leaves"].get(name)
        if entry is None:
            corrupt.append((name, "-", "leaf absent from manifest"))
            continue
        hashed += _check_plane(name, _plane_tag(name), arr, entry, level,
                               corrupt)
        checked += 1
    for name in manifest["leaves"]:
        if name not in seen:
            corrupt.append((name, "-", "manifest leaf missing from params"))
    if manifest["lut"] is not None:
        if state.lut is None:
            corrupt.append(("<lut>", "lut", "LUT missing from state"))
        else:
            hashed += _check_plane("<lut>", "lut", state.lut,
                                   manifest["lut"], level, corrupt)
            checked += 1
    if _table_crc(state.table) != manifest["table_crc32"]:
        corrupt.append(("<table>", "table", "dictionary table crc mismatch"))
    return IntegrityReport(level, not corrupt, corrupt, checked, hashed,
                           time.perf_counter() - t0)


def _plane_tag(name: str) -> str:
    """Trailing attribute of a keyed path ('...w_gate.codes' -> 'codes')."""
    return name.rsplit(".", 1)[-1] if "." in name else name


# ---------------------------------------------------------------------------
# Device-side structural invariants (jittable).
# ---------------------------------------------------------------------------

def _is_container(x) -> bool:
    return isinstance(x, (PackedLinear, TiledPackedLinear, QuantLinear))


def invariant_flags(params, lut) -> dict:
    """Jittable: {leaf name -> bool scalar} structural health per container.

    Packed planes: every code < LUT rows or == ESCAPE; 0 <= nlit <=
    literal capacity; scale/zero finite.  QuantLinear: scale/zero finite.
    Composable into a jitted program — no host sync here.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=_is_container)
    out = {}
    n_rows = lut.shape[0] if lut is not None else 0
    for path, leaf in flat:
        if not _is_container(leaf):
            continue
        name = jax.tree_util.keystr(path)
        ok = jnp.all(jnp.isfinite(leaf.scale)) & \
            jnp.all(jnp.isfinite(leaf.zero))
        if isinstance(leaf, (PackedLinear, TiledPackedLinear)):
            codes = leaf.codes.astype(jnp.uint32)
            ok &= jnp.all((codes < n_rows) | (codes == ESCAPE))
            cap = leaf.literals.shape[-2]
            ok &= jnp.all((leaf.nlit >= 0) & (leaf.nlit <= cap))
        out[name] = ok
    return out


def check_invariants(state) -> IntegrityReport:
    """Host wrapper over :func:`invariant_flags` (one jitted evaluation).

    Catches decode-crashing corruption (out-of-range LUT index, literal
    overflow, non-finite affine) device-side before the first prefill;
    in-range bit flips pass — pair with :func:`verify_serve_state`.
    """
    t0 = time.perf_counter()

    names_holder = []

    @jax.jit
    def run(params, lut):
        flags = invariant_flags(params, lut)
        names_holder.append(list(flags))
        return jnp.stack(list(flags.values())) if flags else jnp.zeros(
            (0,), bool)

    flags = np.asarray(run(state.params, state.lut))
    names = names_holder[0] if names_holder else []
    corrupt = [(n, "invariant", "device-side structural check failed")
               for n, ok in zip(names, flags) if not ok]
    return IntegrityReport("invariant", not corrupt, corrupt, len(names),
                           0, time.perf_counter() - t0)
