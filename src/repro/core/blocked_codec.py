"""TPU-parallel blocked dictionary codec — the hardware adaptation.

The paper's escape stream (``codec.py``) decodes serially: the position of
codeword *i* depends on how many escapes precede it.  On a TPU that is a
non-starter — decode must be a data-parallel gather.  This module keeps the
paper's *dictionary* (same tables, same len-4 byte grams) but re-lays the
stream into a fixed-rate blocked format:

  per tensor, blocks of ``block_weights`` quantized uint8 weights
    codes:    uint16[n_blocks, slots]   slot = one len-S gram; ESCAPE literal
    literals: uint8 [n_blocks, lit_cap, S]  escape grams, packed per block
    nlit:     int32 [n_blocks]          how many escapes in each block

Every block decodes independently: ``rank = cumsum(is_escape) - 1`` inside
the block recovers each escape's literal row.  All three planes are
rectangular → shardable with a plain PartitionSpec on the block axis, and
encode aligns block boundaries to TP shard boundaries (``shard_blocks``).

``decode_blocked_jnp`` is the pure-jnp oracle; the Pallas VMEM kernel lives
in ``repro.kernels.dict_decode``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .codec import ESCAPE, DEFAULT_SEQ_LEN

DEFAULT_BLOCK_WEIGHTS = 4096


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BlockedCompressed:
    """One tensor in the blocked format (+ shared LUT reference)."""

    codes: jax.Array      # uint16[n_blocks, slots]
    literals: jax.Array   # uint8[n_blocks, lit_cap, S]
    nlit: jax.Array       # int32[n_blocks]
    lut: jax.Array        # uint8[n_codes, S] — usually shared across tensors
    orig_len: int         # static
    shape: tuple          # static
    seq_len: int = DEFAULT_SEQ_LEN

    def tree_flatten(self):
        return ((self.codes, self.literals, self.nlit, self.lut),
                (self.orig_len, self.shape, self.seq_len))

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, literals, nlit, lut = children
        orig_len, shape, seq_len = aux
        return cls(codes, literals, nlit, lut, orig_len, shape, seq_len)

    @property
    def payload_nbytes(self) -> int:
        """Bytes for this tensor, excluding the (shared) LUT."""
        return int(self.codes.size * 2 + self.literals.size + self.nlit.size * 4)

    @property
    def slots(self) -> int:
        return self.codes.shape[1]


def build_lut(table: dict, seq_len: int = DEFAULT_SEQ_LEN) -> np.ndarray:
    """Dense decode LUT from a {gram-tuple -> code} table (codec.py builder).

    Row ``code`` holds the gram. Row for ESCAPE never exists (codes are dense
    in [0, len(table))), but we pad one zero row so LUT[code] is always safe.
    """
    n = len(table)
    lut = np.zeros((max(n, 1) + 1, seq_len), dtype=np.uint8)
    for seq, code in table.items():
        lut[code] = np.asarray(seq, dtype=np.uint8)
    return lut


def encode_blocked(weights: np.ndarray, table: dict,
                   lut: np.ndarray | None = None,
                   block_weights: int = DEFAULT_BLOCK_WEIGHTS,
                   seq_len: int = DEFAULT_SEQ_LEN) -> BlockedCompressed:
    """Encode a uint8 tensor into the blocked format (host-side numpy)."""
    assert block_weights % seq_len == 0
    flat = np.ascontiguousarray(weights).reshape(-1).astype(np.uint8)
    orig_len = flat.size
    slots_pb = block_weights // seq_len

    pad = (-orig_len) % block_weights
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.uint8)])
    grams = flat.reshape(-1, seq_len)
    n_blocks = len(grams) // slots_pb

    # Vectorized table lookup via packed uint keys.
    keys = grams.astype(np.uint64)
    packed = np.zeros(len(grams), np.uint64)
    for j in range(seq_len):
        packed = (packed << np.uint64(8)) | keys[:, j]
    klut = {}
    for seq, code in table.items():
        k = 0
        for v in seq:
            k = (k << 8) | int(v)
        klut[k] = code
    codes_flat = np.array([klut.get(int(k), ESCAPE) for k in packed],
                          dtype=np.uint16)

    codes = codes_flat.reshape(n_blocks, slots_pb)
    esc = codes == ESCAPE
    nlit = esc.sum(axis=1).astype(np.int32)
    lit_cap = int(nlit.max()) if n_blocks else 0
    lit_cap = max(lit_cap, 1)
    literals = np.zeros((n_blocks, lit_cap, seq_len), dtype=np.uint8)
    grams_b = grams.reshape(n_blocks, slots_pb, seq_len)
    for b in np.nonzero(nlit)[0]:
        literals[b, : nlit[b]] = grams_b[b][esc[b]]

    if lut is None:
        lut = build_lut(table, seq_len)
    return BlockedCompressed(
        codes=jnp.asarray(codes), literals=jnp.asarray(literals),
        nlit=jnp.asarray(nlit), lut=jnp.asarray(lut),
        orig_len=orig_len, shape=tuple(weights.shape), seq_len=seq_len)


def decode_blocked_jnp(bc: BlockedCompressed) -> jax.Array:
    """Pure-jnp parallel decode — oracle for the Pallas kernel.

    Fully vectorized: dictionary gather + per-block escape-rank gather.
    """
    nb, slots = bc.codes.shape
    s = bc.seq_len
    codes = bc.codes.astype(jnp.int32)
    is_esc = codes == ESCAPE
    # Dictionary path: LUT gather (escape rows read row 0 harmlessly).
    safe = jnp.where(is_esc, 0, codes)
    from_dict = bc.lut[safe]                              # (nb, slots, s)
    # Literal path: rank of each escape within its block recovers its row.
    rank = jnp.cumsum(is_esc.astype(jnp.int32), axis=1) - 1
    rank = jnp.clip(rank, 0, bc.literals.shape[1] - 1)
    from_lit = jax.vmap(lambda lit, r: lit[r])(bc.literals, rank)  # (nb, slots, s)
    out = jnp.where(is_esc[:, :, None], from_lit, from_dict)
    return out.reshape(-1)[: bc.orig_len]


def decode_to(bc: BlockedCompressed, scale: jax.Array, zero: jax.Array,
              dtype=jnp.bfloat16) -> jax.Array:
    """Decode + dequantize to a dense float tensor of the original shape.

    ``scale``/``zero`` follow the per-channel row layout of
    ``QuantConfig(granularity='per_channel')`` against ``bc.shape``.
    """
    flat = decode_blocked_jnp(bc).astype(jnp.float32)
    x = flat.reshape(bc.shape)
    # scale/zero broadcast: (rows, 1) against (rows, cols)
    if scale.ndim == x.ndim - 1 or (scale.ndim == 2 and x.ndim == 2):
        x = (x - zero) * scale
    else:
        x = (x - zero.reshape(-1)) * scale.reshape(-1)
    return x.astype(dtype)


def blocked_nbytes(bc: BlockedCompressed, include_lut: bool = False) -> int:
    n = bc.payload_nbytes
    if include_lut:
        n += int(bc.lut.size)
    return n


# ---------------------------------------------------------------------------
# Tile-aligned layout for the fused decode→dequant→matmul megakernel.
#
# The fused kernel (repro.kernels.fused_decode_matmul) decodes only the
# compressed blocks covering the current (tile_n, tile_k) weight tile inside
# the matmul grid.  That requires each tile to map to a whole number of
# blocks: we re-order the dense (N, K) stream *tile-major* — tile (j, k)
# (row-major over the (N/tile_n, K/tile_k) tile grid) is flattened
# contiguously, so its blocks are the contiguous row range
# [t·bpt, (t+1)·bpt) of the codes/literals planes, with t = j·n_kt + k and
# bpt = tile_n·tile_k / block_weights.
# ---------------------------------------------------------------------------

DEFAULT_TILE_N = 128   # matches dequant_matmul.DEFAULT_BN
DEFAULT_TILE_K = 512   # matches dequant_matmul.DEFAULT_BK


def _pow2_divisor(n: int, cap: int) -> int:
    """Largest power of two that divides ``n``, capped at ``cap``."""
    d = n & (-n)  # largest power-of-2 factor
    return min(d, cap)


def _shrink_block_weights(vol: int, block_weights: int, seq_len: int) -> int:
    """Halve a tile's volume down toward the ``block_weights`` cap while it
    stays a whole number of ``seq_len`` grams — the single source of truth
    for the fused layout's actual block size."""
    bw = vol
    while bw > block_weights and bw % 2 == 0 and (bw // 2) % seq_len == 0:
        bw //= 2
    return bw


def choose_fused_tiles(shape: tuple, block_weights: int = DEFAULT_BLOCK_WEIGHTS,
                       seq_len: int = DEFAULT_SEQ_LEN,
                       max_tile_n: int = DEFAULT_TILE_N,
                       max_tile_k: int = DEFAULT_TILE_K,
                       shards: tuple = (1, 1)):
    """Pick (tile_n, tile_k, block_weights) for the fused-kernel layout.

    Tiles are the largest power-of-two divisors of (N, K) up to the kernel's
    default matmul block — divisors, not round-ups, so no padding is ever
    needed and decoded bytes are bit-identical to the linear layout's.
    Returns None when the tensor cannot host a tile of at least one
    ``seq_len`` gram (fused layout unavailable; callers fall back to the
    linear layout + two-step path).

    ``shards=(sn, sk)``: intended mesh sharding of the dense dims.  Tiles
    are chosen to divide the *per-shard* dims (n/sn, k/sk) so the
    shard-mapped fused path can split the tile-major block axis along
    whole out-tile bands (see ``kernels.ops``); a per-shard divisor also
    divides the full dim, so the single-device fused path is unaffected.
    A shard count that does not divide its dim is ignored (that axis
    cannot take the sharded fused path anyway).
    """
    n, k = int(shape[0]), int(shape[1])
    if n <= 0 or k <= 0:
        return None
    sn, sk = int(shards[0]) or 1, int(shards[1]) or 1
    if sn > 1 and n % sn == 0:
        n //= sn
    if sk > 1 and k % sk == 0:
        k //= sk
    tn = _pow2_divisor(n, max_tile_n)
    tk = _pow2_divisor(k, max_tile_k)
    vol = tn * tk
    if vol % seq_len:
        return None
    bw = _shrink_block_weights(vol, block_weights, seq_len)
    if vol % bw or bw % seq_len:
        return None
    return tn, tk, bw


def tile_stream(w2d: np.ndarray, tile_n: int, tile_k: int) -> np.ndarray:
    """Re-order a (N, K) array into the tile-major flat byte stream."""
    n, k = w2d.shape
    assert n % tile_n == 0 and k % tile_k == 0, (w2d.shape, tile_n, tile_k)
    return (np.ascontiguousarray(w2d)
            .reshape(n // tile_n, tile_n, k // tile_k, tile_k)
            .transpose(0, 2, 1, 3).reshape(-1))


def untile_flat(flat, shape: tuple, tile_n: int, tile_k: int):
    """Inverse of :func:`tile_stream` for (..., N·K) flats (jnp or numpy)."""
    n, k = shape
    lead = flat.shape[:-1]
    x = flat.reshape(lead + (n // tile_n, k // tile_k, tile_n, tile_k))
    x = jnp.moveaxis(x, -3, -2) if isinstance(flat, jax.Array) else \
        np.moveaxis(x, -3, -2)
    return x.reshape(lead + (n, k))


def encode_blocked_tiled(weights2d: np.ndarray, table: dict,
                         lut: np.ndarray | None = None,
                         tile_n: int = DEFAULT_TILE_N,
                         tile_k: int = DEFAULT_TILE_K,
                         block_weights: int = DEFAULT_BLOCK_WEIGHTS,
                         seq_len: int = DEFAULT_SEQ_LEN) -> BlockedCompressed:
    """Encode a (N, K) uint8 tensor in the fused-kernel tile-major layout.

    ``block_weights`` is a *cap*: the actual block size is shrunk so a tile
    always holds a whole number of blocks (see :func:`choose_fused_tiles`).
    """
    n, k = weights2d.shape
    vol = tile_n * tile_k
    bw = _shrink_block_weights(vol, block_weights, seq_len)
    assert vol % bw == 0 and bw % seq_len == 0, (tile_n, tile_k, bw, seq_len)
    stream = tile_stream(np.asarray(weights2d, dtype=np.uint8),
                         tile_n, tile_k)
    bc = encode_blocked(stream, table, lut=lut, block_weights=bw,
                        seq_len=seq_len)
    assert bc.orig_len == n * k  # tiles divide exactly; no codec padding
    return dataclasses.replace(bc, shape=(n, k))


def shard_aligned_block_weights(tensor_cols: int, tp_shards: int,
                                block_weights: int = DEFAULT_BLOCK_WEIGHTS,
                                seq_len: int = DEFAULT_SEQ_LEN) -> int:
    """Pick a block size so TP shard boundaries coincide with block
    boundaries: shard_size % block == 0 when possible, else shrink block to
    gcd alignment (never below seq_len)."""
    shard = tensor_cols // tp_shards if tp_shards and tensor_cols % tp_shards == 0 else tensor_cols
    b = min(block_weights, max(seq_len, shard))
    while shard % b and b > seq_len:
        b //= 2
    return max(b - (b % seq_len), seq_len)
