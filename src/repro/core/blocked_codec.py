"""TPU-parallel blocked dictionary codec — the hardware adaptation.

The paper's escape stream (``codec.py``) decodes serially: the position of
codeword *i* depends on how many escapes precede it.  On a TPU that is a
non-starter — decode must be a data-parallel gather.  This module keeps the
paper's *dictionary* (same tables, same len-4 byte grams) but re-lays the
stream into a fixed-rate blocked format:

  per tensor, blocks of ``block_weights`` quantized uint8 weights
    codes:    uint16[n_blocks, slots]   slot = one len-S gram; ESCAPE literal
    literals: uint8 [n_blocks, lit_cap, S]  escape grams, packed per block
    nlit:     int32 [n_blocks]          how many escapes in each block

Every block decodes independently: ``rank = cumsum(is_escape) - 1`` inside
the block recovers each escape's literal row.  All three planes are
rectangular → shardable with a plain PartitionSpec on the block axis, and
encode aligns block boundaries to TP shard boundaries (``shard_blocks``).

``decode_blocked_jnp`` is the pure-jnp oracle; the Pallas VMEM kernel lives
in ``repro.kernels.dict_decode``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .codec import ESCAPE, DEFAULT_SEQ_LEN

DEFAULT_BLOCK_WEIGHTS = 4096


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BlockedCompressed:
    """One tensor in the blocked format (+ shared LUT reference)."""

    codes: jax.Array      # uint16[n_blocks, slots]
    literals: jax.Array   # uint8[n_blocks, lit_cap, S]
    nlit: jax.Array       # int32[n_blocks]
    lut: jax.Array        # uint8[n_codes, S] — usually shared across tensors
    orig_len: int         # static
    shape: tuple          # static
    seq_len: int = DEFAULT_SEQ_LEN

    def tree_flatten(self):
        return ((self.codes, self.literals, self.nlit, self.lut),
                (self.orig_len, self.shape, self.seq_len))

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, literals, nlit, lut = children
        orig_len, shape, seq_len = aux
        return cls(codes, literals, nlit, lut, orig_len, shape, seq_len)

    @property
    def payload_nbytes(self) -> int:
        """Bytes for this tensor, excluding the (shared) LUT."""
        return int(self.codes.size * 2 + self.literals.size + self.nlit.size * 4)

    @property
    def slots(self) -> int:
        return self.codes.shape[1]


def build_lut(table: dict, seq_len: int = DEFAULT_SEQ_LEN) -> np.ndarray:
    """Dense decode LUT from a {gram-tuple -> code} table (codec.py builder).

    Row ``code`` holds the gram. Row for ESCAPE never exists (codes are dense
    in [0, len(table))), but we pad one zero row so LUT[code] is always safe.
    """
    n = len(table)
    lut = np.zeros((max(n, 1) + 1, seq_len), dtype=np.uint8)
    for seq, code in table.items():
        lut[code] = np.asarray(seq, dtype=np.uint8)
    return lut


def encode_blocked(weights: np.ndarray, table: dict,
                   lut: np.ndarray | None = None,
                   block_weights: int = DEFAULT_BLOCK_WEIGHTS,
                   seq_len: int = DEFAULT_SEQ_LEN) -> BlockedCompressed:
    """Encode a uint8 tensor into the blocked format (host-side numpy)."""
    assert block_weights % seq_len == 0
    flat = np.ascontiguousarray(weights).reshape(-1).astype(np.uint8)
    orig_len = flat.size
    slots_pb = block_weights // seq_len

    pad = (-orig_len) % block_weights
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.uint8)])
    grams = flat.reshape(-1, seq_len)
    n_blocks = len(grams) // slots_pb

    # Vectorized table lookup via packed uint keys.
    keys = grams.astype(np.uint64)
    packed = np.zeros(len(grams), np.uint64)
    for j in range(seq_len):
        packed = (packed << np.uint64(8)) | keys[:, j]
    klut = {}
    for seq, code in table.items():
        k = 0
        for v in seq:
            k = (k << 8) | int(v)
        klut[k] = code
    codes_flat = np.array([klut.get(int(k), ESCAPE) for k in packed],
                          dtype=np.uint16)

    codes = codes_flat.reshape(n_blocks, slots_pb)
    esc = codes == ESCAPE
    nlit = esc.sum(axis=1).astype(np.int32)
    lit_cap = int(nlit.max()) if n_blocks else 0
    lit_cap = max(lit_cap, 1)
    literals = np.zeros((n_blocks, lit_cap, seq_len), dtype=np.uint8)
    grams_b = grams.reshape(n_blocks, slots_pb, seq_len)
    for b in np.nonzero(nlit)[0]:
        literals[b, : nlit[b]] = grams_b[b][esc[b]]

    if lut is None:
        lut = build_lut(table, seq_len)
    return BlockedCompressed(
        codes=jnp.asarray(codes), literals=jnp.asarray(literals),
        nlit=jnp.asarray(nlit), lut=jnp.asarray(lut),
        orig_len=orig_len, shape=tuple(weights.shape), seq_len=seq_len)


def decode_blocked_jnp(bc: BlockedCompressed) -> jax.Array:
    """Pure-jnp parallel decode — oracle for the Pallas kernel.

    Fully vectorized: dictionary gather + per-block escape-rank gather.
    """
    nb, slots = bc.codes.shape
    s = bc.seq_len
    codes = bc.codes.astype(jnp.int32)
    is_esc = codes == ESCAPE
    # Dictionary path: LUT gather (escape rows read row 0 harmlessly).
    safe = jnp.where(is_esc, 0, codes)
    from_dict = bc.lut[safe]                              # (nb, slots, s)
    # Literal path: rank of each escape within its block recovers its row.
    rank = jnp.cumsum(is_esc.astype(jnp.int32), axis=1) - 1
    rank = jnp.clip(rank, 0, bc.literals.shape[1] - 1)
    from_lit = jax.vmap(lambda lit, r: lit[r])(bc.literals, rank)  # (nb, slots, s)
    out = jnp.where(is_esc[:, :, None], from_lit, from_dict)
    return out.reshape(-1)[: bc.orig_len]


def decode_to(bc: BlockedCompressed, scale: jax.Array, zero: jax.Array,
              dtype=jnp.bfloat16) -> jax.Array:
    """Decode + dequantize to a dense float tensor of the original shape.

    ``scale``/``zero`` follow the per-channel row layout of
    ``QuantConfig(granularity='per_channel')`` against ``bc.shape``.
    """
    flat = decode_blocked_jnp(bc).astype(jnp.float32)
    x = flat.reshape(bc.shape)
    # scale/zero broadcast: (rows, 1) against (rows, cols)
    if scale.ndim == x.ndim - 1 or (scale.ndim == 2 and x.ndim == 2):
        x = (x - zero) * scale
    else:
        x = (x - zero.reshape(-1)) * scale.reshape(-1)
    return x.astype(dtype)


def blocked_nbytes(bc: BlockedCompressed, include_lut: bool = False) -> int:
    n = bc.payload_nbytes
    if include_lut:
        n += int(bc.lut.size)
    return n


def shard_aligned_block_weights(tensor_cols: int, tp_shards: int,
                                block_weights: int = DEFAULT_BLOCK_WEIGHTS,
                                seq_len: int = DEFAULT_SEQ_LEN) -> int:
    """Pick a block size so TP shard boundaries coincide with block
    boundaries: shard_size % block == 0 when possible, else shrink block to
    gcd alignment (never below seq_len)."""
    shard = tensor_cols // tp_shards if tp_shards and tensor_cols % tp_shards == 0 else tensor_cols
    b = min(block_weights, max(seq_len, shard))
    while shard % b and b > seq_len:
        b //= 2
    return max(b - (b % seq_len), seq_len)
