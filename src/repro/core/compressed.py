"""Compressed parameter containers — how models carry Tiny-QMoE weights.

A linear weight in ``mode='compressed'`` serving is stored as a
:class:`PackedLinear`: blocked-codec planes (codes/literals/nlit) plus the
quantizer's per-channel (scale, zero).  The decode LUT is *shared* across the
whole model (one dictionary per model, as in the paper) and passed alongside
the params, so stacking layers for ``lax.scan`` never duplicates it.

Three weight modes, matching the paper's evaluation triple:
  dense      — bf16 weights (paper's uncompressed row)
  quant      — int8 payload + scale/zero (paper's "Quantized" row)
  compressed — PackedLinear (paper's "Compressed" row)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import blocked_codec as bcdc
from .blocked_codec import BlockedCompressed, DEFAULT_BLOCK_WEIGHTS
from .codec import DEFAULT_SEQ_LEN
from .quant import QuantConfig, quantize

WeightMode = str  # 'dense' | 'quant' | 'compressed'


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class QuantLinear:
    """int8 weight + per-channel affine params (mode='quant')."""

    values: jax.Array   # uint8[out, in] (or [L, out, in] stacked)
    scale: jax.Array    # f32[out, 1]
    zero: jax.Array     # f32[out, 1]

    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        return (((ga("values"), self.values), (ga("scale"), self.scale),
                 (ga("zero"), self.zero)), ())

    def tree_flatten(self):
        return (self.values, self.scale, self.zero), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def materialize(self, dtype=jnp.bfloat16) -> jax.Array:
        return ((self.values.astype(jnp.float32) - self.zero) * self.scale
                ).astype(dtype)


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class PackedLinear:
    """Blocked-compressed int8 weight + quantizer params (mode='compressed').

    Shapes (single layer):
      codes    uint16[nb, slots]
      literals uint8 [nb, cap, S]
      nlit     int32 [nb]
      scale    f32   [out, 1]
      zero     f32   [out, 1]
    Stacked layer variants carry a leading L dim on every plane.

    Registered *with keys* so partition rules see ".../w_gate/codes" paths —
    plain node registration loses the names and every plane silently
    replicates (51 GiB/dev of codes at llama3-405b; §Perf iteration 4).
    """

    codes: jax.Array
    literals: jax.Array
    nlit: jax.Array
    scale: jax.Array
    zero: jax.Array
    shape: tuple          # static (out, in) of the dense weight
    seq_len: int = DEFAULT_SEQ_LEN
    # consumer contracts the model-sharded dim (wo/w_down): the decoded
    # dense weight must reshard (u8 bytes) instead of the activations
    # (§Perf P2); set from the partition rule table at build/spec time.
    row_parallel: bool = False
    # Fused-kernel tile layout (core.blocked_codec tile-major ordering):
    # tile_n > 0 means blocks are grouped per (tile_n, tile_k) weight tile
    # so the fused decode→dequant→matmul megakernel can stream them; 0 =
    # linear layout (two-step decode path only).
    tile_n: int = 0
    tile_k: int = 0

    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        return (((ga("codes"), self.codes), (ga("literals"), self.literals),
                 (ga("nlit"), self.nlit), (ga("scale"), self.scale),
                 (ga("zero"), self.zero)),
                (self.shape, self.seq_len, self.row_parallel,
                 self.tile_n, self.tile_k))

    def tree_flatten(self):
        return ((self.codes, self.literals, self.nlit, self.scale, self.zero),
                (self.shape, self.seq_len, self.row_parallel,
                 self.tile_n, self.tile_k))

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, literals, nlit, scale, zero = children
        shape, seq_len, row_parallel, tile_n, tile_k = aux
        return cls(codes, literals, nlit, scale, zero, shape, seq_len,
                   row_parallel, tile_n, tile_k)

    @property
    def payload_nbytes(self) -> int:
        return int(self.codes.size * 2 + self.literals.size + self.nlit.size * 4)

    def degather(self) -> "PackedLinear":
        """Reshard planes to model-axis-only before decoding.

        FSDP-stored planes shard (data×model); without this, SPMD decodes
        locally and then all-gathers the DEQUANTIZED f32 dense weight over
        the data axis — 3.25 GiB/layer on llama3-405b decode, 410 GiB/step
        (§Perf D1).  Constraining the planes first moves the gather onto
        the compressed u16/u8 bytes (~7× fewer, and it IS the paper's
        point: ship compressed bytes, decode close to compute).
        """
        from repro.sharding.partition import constrain

        def on_block_axis(x, rank):
            # keep the pod dim in the plane sharding: the degather then
            # spans only the in-pod data axis (ICI), never the cross-pod
            # DCN links — each pod decodes its row range and the small
            # activation combine crosses pods instead (§Perf D1b).
            lead = x.ndim - rank
            return constrain(x, *([None] * lead), ("pod", "model"),
                             *([None] * (rank - 1)))

        return PackedLinear(
            codes=on_block_axis(self.codes, 2),
            literals=on_block_axis(self.literals, 3),
            nlit=on_block_axis(self.nlit, 1),
            scale=self.scale, zero=self.zero,
            shape=self.shape, seq_len=self.seq_len,
            row_parallel=self.row_parallel,
            tile_n=self.tile_n, tile_k=self.tile_k)

    def materialize_int8(self, lut: jax.Array) -> jax.Array:
        """Decode only (uint8 codes of the quantized weight).  Handles
        arbitrary leading (stacked layer/expert) dims: blocks decode
        independently, so (..., nb, slots) reshapes to (-1, slots)."""
        self = self.degather()
        lead = self.codes.shape[:-2]
        nb, slots = self.codes.shape[-2:]
        cap = self.literals.shape[-2]
        n_dense = int(np.prod(self.shape))
        codes = self.codes.reshape(-1, slots)
        lits = self.literals.reshape(-1, cap, self.seq_len)
        nlit = self.nlit.reshape(-1)
        bc = BlockedCompressed(codes, lits, nlit, lut,
                               orig_len=codes.shape[0] * slots * self.seq_len,
                               shape=(), seq_len=self.seq_len)
        flat = bcdc.decode_blocked_jnp(bc)
        per = nb * slots * self.seq_len
        flat = flat.reshape((-1, per))[:, :n_dense]
        if self.tile_n:  # undo the fused-kernel tile-major ordering
            return bcdc.untile_flat(flat.reshape(lead + (n_dense,)),
                                    tuple(self.shape),
                                    self.tile_n, self.tile_k)
        return flat.reshape(lead + tuple(self.shape))

    def materialize(self, lut: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
        """Decode + dequantize to the dense weight (any leading dims)."""
        w = self.materialize_int8(lut).astype(jnp.float32)
        return ((w - self.zero) * self.scale).astype(dtype)


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class TiledPackedLinear:
    """2D-sharded compressed weight: column tiles on the data axis.

    The plain PackedLinear FSDPs its block axis across (data×model) and
    must gather the planes on every use — at decode that streams the whole
    compressed model over ICI per token (§Perf D1/D2).  Here the dense
    (out, in) weight is split into ``tiles`` column groups; each tile is
    encoded separately, the tile axis shards on (pod, data) and the block
    axis on model, so every device permanently owns a (out/model ×
    in/data) compressed tile: NO weight collective at use time.  The
    matmul contracts x's feature dim against the data axis (activation
    reshard, ~MB) — classic 2D tensor parallelism, applied to the paper's
    compressed format.

    Plane names carry a ``_t`` suffix so partition rules can tell tiled
    planes from stacked-expert PackedLinear planes of equal rank.

    Shapes (single layer):
      codes_t    uint16[tiles, nb, slots]
      literals_t uint8 [tiles, nb, cap, S]
      nlit_t     int32 [tiles, nb]
      scale/zero f32   [out, 1]

    ``tile_n/tile_k > 0``: each column tile is encoded in the fused-kernel
    tile-major layout (``blocked_codec.encode_blocked_tiled`` over the
    (out, in/tiles) sub-weight), so the shard-mapped fused megakernel can
    run each device's resident tile without materializing it; 0 = linear
    per-tile layout (dense-materialize 2D-TP path only).
    """

    codes: jax.Array
    literals: jax.Array
    nlit: jax.Array
    scale: jax.Array
    zero: jax.Array
    shape: tuple          # static (out, in) of the dense weight
    seq_len: int = DEFAULT_SEQ_LEN
    tile_n: int = 0
    tile_k: int = 0

    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        return (((ga("codes_t"), self.codes),
                 (ga("literals_t"), self.literals),
                 (ga("nlit_t"), self.nlit), (ga("scale"), self.scale),
                 (ga("zero"), self.zero)),
                (self.shape, self.seq_len, self.tile_n, self.tile_k))

    def tree_flatten(self):
        return ((self.codes, self.literals, self.nlit, self.scale,
                 self.zero),
                (self.shape, self.seq_len, self.tile_n, self.tile_k))

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, literals, nlit, scale, zero = children
        shape, seq_len, tile_n, tile_k = aux
        return cls(codes, literals, nlit, scale, zero, shape, seq_len,
                   tile_n, tile_k)

    @property
    def tiles(self) -> int:
        return self.codes.shape[-3]

    @property
    def payload_nbytes(self) -> int:
        return int(self.codes.size * 2 + self.literals.size +
                   self.nlit.size * 4)

    def materialize_int8(self, lut: jax.Array) -> jax.Array:
        """Decode every tile locally → dense (..., out, in) uint8 whose in
        dim is tile-sharded (no plane collectives)."""
        lead = self.codes.shape[:-3]
        tiles, nb, slots = self.codes.shape[-3:]
        cap = self.literals.shape[-2]
        out, in_full = self.shape
        in_t = in_full // tiles
        codes = self.codes.reshape(-1, slots)
        lits = self.literals.reshape(-1, cap, self.seq_len)
        nlit = self.nlit.reshape(-1)
        bc = BlockedCompressed(codes, lits, nlit, lut,
                               orig_len=codes.shape[0] * slots * self.seq_len,
                               shape=(), seq_len=self.seq_len)
        flat = bcdc.decode_blocked_jnp(bc)
        per_tile = nb * slots * self.seq_len
        flat = flat.reshape((-1, tiles, per_tile))[..., : out * in_t]
        if self.tile_n:  # undo the per-tile fused tile-major ordering
            flat = bcdc.untile_flat(flat, (out, in_t), self.tile_n,
                                    self.tile_k)
        w = flat.reshape(lead + (tiles, out, in_t))
        w = jnp.moveaxis(w, -3, -2)                      # (..., out, tiles, in_t)
        return w.reshape(lead + (out, in_full))

    def materialize(self, lut: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
        w = self.materialize_int8(lut).astype(jnp.float32)
        return ((w - self.zero) * self.scale).astype(dtype)


def encode_tiled_planes(vals: np.ndarray, table: dict, lut: np.ndarray,
                        tiles: int,
                        block_weights: int = DEFAULT_BLOCK_WEIGHTS,
                        tile=None, shards: tuple = (1, 1)):
    """Encode a quantized (out, in) uint8 tensor as per-column-tile planes.

    Returns ``(bcs, tile_n, tile_k)`` — one BlockedCompressed per column
    tile (literal caps NOT yet unified; callers pad to a shared cap).
    ``tile=(tn, tk)`` or ``"auto"`` selects the fused-kernel tile-major
    layout per tile; ``shards=(model_shards, 1)`` makes the auto choice
    divide the per-model-shard out dim (see
    :func:`blocked_codec.choose_fused_tiles`).  ``tile=None`` keeps the
    legacy linear per-tile layout (tile_n = tile_k = 0).
    """
    out, in_full = vals.shape
    assert in_full % tiles == 0, (vals.shape, tiles)
    in_t = in_full // tiles
    if tile == "auto":
        picked = bcdc.choose_fused_tiles((out, in_t), block_weights,
                                         shards=shards)
        tile = picked[:2] if picked else None
    bw = min(block_weights, ((out * in_t) // DEFAULT_SEQ_LEN)
             * DEFAULT_SEQ_LEN) or DEFAULT_SEQ_LEN
    bcs = []
    for t in range(tiles):
        sub = np.ascontiguousarray(vals[:, t * in_t:(t + 1) * in_t])
        if tile is not None:
            bcs.append(bcdc.encode_blocked_tiled(
                sub, table, lut=lut, tile_n=tile[0], tile_k=tile[1],
                block_weights=bw))
        else:
            bcs.append(bcdc.encode_blocked(sub, table, lut=lut,
                                           block_weights=bw))
    tn, tk = tile if tile is not None else (0, 0)
    return bcs, tn, tk


def pad_literals(literals: jax.Array, cap: int) -> jax.Array:
    """Pad a (..., cur_cap, S) literal plane up to a uniform capacity."""
    cur = literals.shape[-2]
    if cur > cap:
        raise ValueError(f"lit_cap {cap} < needed {cur}")
    if cur == cap:
        return literals
    widths = [(0, 0)] * literals.ndim
    widths[-2] = (0, cap - cur)
    return jnp.pad(literals, widths)


def pack_linear_tiled(w: jax.Array, table: dict, lut: np.ndarray,
                      tiles: int, qcfg: QuantConfig | None = None,
                      block_weights: int = DEFAULT_BLOCK_WEIGHTS,
                      lit_cap: int | None = None,
                      tile=None, shards: tuple = (1, 1)) -> TiledPackedLinear:
    """Quantize + encode each column tile separately (host side).

    ``tile``/``shards`` select the fused tile-major per-tile layout (see
    :func:`encode_tiled_planes`); the default keeps the linear layout.
    """
    ql = quantize_linear(w, qcfg)
    bcs, tn, tk = encode_tiled_planes(
        np.asarray(ql.values, dtype=np.uint8), table, lut, tiles,
        block_weights=block_weights, tile=tile, shards=shards)
    cap = lit_cap if lit_cap is not None else max(
        bc.literals.shape[1] for bc in bcs)
    return TiledPackedLinear(
        codes=jnp.stack([bc.codes for bc in bcs]),
        literals=jnp.stack([pad_literals(bc.literals, cap) for bc in bcs]),
        nlit=jnp.stack([bc.nlit for bc in bcs]),
        scale=ql.scale, zero=ql.zero,
        shape=tuple(w.shape), seq_len=DEFAULT_SEQ_LEN,
        tile_n=tn, tile_k=tk)


def planned_tiled_specs(shape: tuple, tiles: int, *, stacked: tuple = (),
                        block_weights: int = DEFAULT_BLOCK_WEIGHTS,
                        seq_len: int = DEFAULT_SEQ_LEN,
                        lit_cap_frac: float = 0.25,
                        tile_n: int = 0,
                        tile_k: int = 0) -> TiledPackedLinear:
    """ShapeDtypeStruct stand-in for a TiledPackedLinear.

    ``tile_n/tile_k`` mirror the fused tile-major layout of
    :func:`pack_linear_tiled` (block size shrunk to divide the tile
    volume); 0 keeps the linear per-tile layout.
    """
    out, in_full = shape
    in_t = in_full // tiles
    n = out * in_t
    bw = min(block_weights, (n // seq_len) * seq_len) or seq_len
    if tile_n:
        bw = bcdc._shrink_block_weights(tile_n * tile_k, bw, seq_len)
        nb = n // bw
    else:
        nb = -(-n // bw)
    slots = bw // seq_len
    cap = max(1, int(slots * lit_cap_frac))
    sds = jax.ShapeDtypeStruct
    return TiledPackedLinear(
        codes=sds(stacked + (tiles, nb, slots), jnp.uint16),
        literals=sds(stacked + (tiles, nb, cap, seq_len), jnp.uint8),
        nlit=sds(stacked + (tiles, nb), jnp.int32),
        scale=sds(stacked + (out, 1), jnp.float32),
        zero=sds(stacked + (out, 1), jnp.float32),
        shape=tuple(shape), seq_len=seq_len, tile_n=tile_n, tile_k=tile_k)


# ---------------------------------------------------------------------------
# Host-side packing of real weights.
# ---------------------------------------------------------------------------

def quantize_linear(w: jax.Array, qcfg: QuantConfig | None = None) -> QuantLinear:
    """Quantize a (out, in) weight to the QuantLinear container."""
    qcfg = qcfg or QuantConfig(bits=8, granularity="per_channel")
    qt = quantize(jnp.asarray(w), qcfg)
    values = qt.values.reshape(w.shape)  # per_channel rows == w rows
    return QuantLinear(values=values.astype(jnp.uint8),
                       scale=qt.scale, zero=qt.zero)


def pack_expert_stack(ws, table: dict | None = None,
                      block_weights: int = DEFAULT_BLOCK_WEIGHTS,
                      tile="auto"):
    """Quantize + blocked-compress a list of same-shape expert weights into
    one stacked PackedLinear (leading expert axis on every plane, one
    shared dictionary, uniform literal cap; tile-major by default) — the
    host-side mirror of what ``engine.build_serve_params`` emits for
    ``experts/w_*`` leaves.  Returns ``(packed, lut)`` with ``lut`` as a
    device array.  ``tile=None`` keeps the linear layout (grouped-kernel
    ineligible; two-step fallback), for tests of the fallback path.
    """
    from .codec import find_frequent_sequences

    n, k = ws[0].shape
    qls = [quantize_linear(jnp.asarray(w)) for w in ws]
    if table is None:
        table = find_frequent_sequences([np.asarray(q.values) for q in qls])
    lut = bcdc.build_lut(table)
    if tile == "auto":
        picked = bcdc.choose_fused_tiles((n, k), block_weights)
        tile = picked[:2] if picked else None
    if tile is not None:
        tn, tk = tile
        bcs = [bcdc.encode_blocked_tiled(np.asarray(q.values), table,
                                         lut=lut, tile_n=tn, tile_k=tk,
                                         block_weights=block_weights)
               for q in qls]
    else:
        tn, tk = 0, 0
        bcs = [bcdc.encode_blocked(np.asarray(q.values), table, lut=lut,
                                   block_weights=block_weights)
               for q in qls]
    cap = max(bc.literals.shape[1] for bc in bcs)
    packed = PackedLinear(
        codes=jnp.stack([bc.codes for bc in bcs]),
        literals=jnp.stack([pad_literals(bc.literals, cap) for bc in bcs]),
        nlit=jnp.stack([bc.nlit for bc in bcs]),
        scale=jnp.stack([q.scale for q in qls]),
        zero=jnp.stack([q.zero for q in qls]),
        shape=(n, k), tile_n=tn, tile_k=tk)
    return packed, jnp.asarray(lut)


def pack_linear(w: jax.Array, table: dict, lut: np.ndarray,
                qcfg: QuantConfig | None = None,
                block_weights: int = DEFAULT_BLOCK_WEIGHTS,
                lit_cap: int | None = None,
                tile: tuple | None = None) -> PackedLinear:
    """Quantize + blocked-compress a dense weight (host side).

    ``lit_cap`` forces a uniform literal capacity (needed when stacking
    layers); pass None to use the tensor's own max.  ``tile=(tile_n,
    tile_k)`` encodes in the fused-megakernel tile-major layout (pass
    ``"auto"`` to let :func:`blocked_codec.choose_fused_tiles` pick); None
    keeps the linear layout.
    """
    ql = quantize_linear(w, qcfg)
    if tile == "auto":
        picked = bcdc.choose_fused_tiles(w.shape, block_weights)
        tile = picked[:2] if picked else None
    if tile is not None:
        tn, tk = tile
        bc = bcdc.encode_blocked_tiled(np.asarray(ql.values), table, lut=lut,
                                       tile_n=tn, tile_k=tk,
                                       block_weights=block_weights)
    else:
        bc = bcdc.encode_blocked(np.asarray(ql.values), table,
                                 lut=lut, block_weights=block_weights)
    literals = bc.literals
    if lit_cap is not None:
        cur = literals.shape[1]
        if cur < lit_cap:
            pad = jnp.zeros((literals.shape[0], lit_cap - cur,
                             literals.shape[2]), jnp.uint8)
            literals = jnp.concatenate([literals, pad], axis=1)
        elif cur > lit_cap:
            raise ValueError(f"lit_cap {lit_cap} < needed {cur}")
    tn, tk = tile if tile is not None else (0, 0)
    return PackedLinear(codes=bc.codes, literals=literals, nlit=bc.nlit,
                        scale=ql.scale, zero=ql.zero, shape=tuple(w.shape),
                        seq_len=bc.seq_len, tile_n=tn, tile_k=tk)


# ---------------------------------------------------------------------------
# Dry-run shape planning (no data, deterministic shapes).
# ---------------------------------------------------------------------------

def planned_packed_specs(shape: tuple, *, stacked: tuple = (),
                         block_weights: int = DEFAULT_BLOCK_WEIGHTS,
                         seq_len: int = DEFAULT_SEQ_LEN,
                         lit_cap_frac: float = 0.25,
                         tile_n: int = 0,
                         tile_k: int = 0) -> PackedLinear:
    """ShapeDtypeStruct stand-in for a PackedLinear of a given dense shape.

    ``lit_cap_frac`` is the planned escape rate (fraction of slots carrying
    literals); 0.25 is the measured rate on 8-bit quantized transformer
    weights with a 64k dictionary (see benchmarks/compression.py).

    ``tile_n/tile_k`` mirror the fused tile-major layout of
    :func:`pack_linear` / ``engine.build_serve_params`` (block size shrunk
    to divide the tile volume, no round-up padding), so dry-run lowering
    dispatches through the fused megakernel paths exactly like real
    serving; 0 keeps the legacy linear layout (two-step path).
    """
    n = int(np.prod(shape))
    if tile_n:
        bw = bcdc._shrink_block_weights(tile_n * tile_k, block_weights,
                                        seq_len)
        nb = n // bw
    else:
        bw = block_weights
        nb = -(-n // bw)
    slots = bw // seq_len
    cap = max(1, int(slots * lit_cap_frac))
    sds = jax.ShapeDtypeStruct
    out = shape[0]
    return PackedLinear(
        codes=sds(stacked + (nb, slots), jnp.uint16),
        literals=sds(stacked + (nb, cap, seq_len), jnp.uint8),
        nlit=sds(stacked + (nb,), jnp.int32),
        scale=sds(stacked + (out, 1), jnp.float32),
        zero=sds(stacked + (out, 1), jnp.float32),
        shape=tuple(shape), seq_len=seq_len, tile_n=tile_n, tile_k=tile_k)


def planned_quant_specs(shape: tuple, *, stacked: tuple = ()) -> QuantLinear:
    sds = jax.ShapeDtypeStruct
    return QuantLinear(
        values=sds(stacked + tuple(shape), jnp.uint8),
        scale=sds(stacked + (shape[0], 1), jnp.float32),
        zero=sds(stacked + (shape[0], 1), jnp.float32))


def lut_spec(n_codes: int = 65536, seq_len: int = DEFAULT_SEQ_LEN):
    return jax.ShapeDtypeStruct((n_codes, seq_len), jnp.uint8)
