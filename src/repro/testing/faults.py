"""Fault-injection harness — makes every resilience path provable in CI.

Three fault families, mirroring what a flash-backed edge deployment
actually sees:

  * **Artifact corruption** — ``flip_bit`` / ``flip_lut_bit`` flip a
    seeded bit inside a named plane (codes, literals, nlit, scale, zero)
    or the model-wide LUT of a ``ServeState``; ``verify_serve_state``
    must name the leaf.
  * **Checkpoint damage** — ``uncommit_step`` removes the COMMIT marker
    (torn write), ``truncate_step`` chops a shard file mid-byte,
    ``corrupt_step`` flips payload bits post-commit (bit rot);
    ``checkpoint.restore_latest`` must fall back to the previous
    committed step.
  * **Runtime errors** — ``failing(fn, times)`` wraps any callable to
    raise ``jax.errors.JaxRuntimeError`` for its first N calls (the
    transient-device-fault model, at the request seam);
    ``decode_fault(nth)`` arms a *real in-graph* fault: an ordered
    ``io_callback`` threaded into ``ops.decode_dequant_matmul`` raises on
    the Nth kernel execution, so the error surfaces as a genuine
    ``JaxRuntimeError`` from inside the jitted decode scan — exactly the
    failure the ``ResilientEngine`` ladder exists for.  The injection
    skips traces where the session impl lever pins a fallback rung
    ('unfused'/'materialize'), modelling "the fused path is broken, the
    fallback paths are not".
  * **Scheduler faults** — the request-level families driving the
    continuous-batching robustness matrix (serve/scheduler.py).
    ``slot_fault(slot, nth)`` is the *poisoned-request* model: the
    scheduler's decode step raises whenever the target slot is active
    (from its nth such call), on every ladder rung — the fault follows
    the request, not the kernel, so only quarantine-by-bisection can
    isolate it.  ``alloc_failure(times)`` injects page-pool exhaustion
    at the KV-pool alloc seam, driving the preempt/requeue path without
    having to construct an overcommitted pool.
  * **Memory pressure** — ``pressure_trace(kind, ...)`` builds a seeded
    per-step HBM-budget trace (step / spike / ramp / oscillate — the
    jetsam-style reclaim shapes a 4–8 GB unified-memory device sees) and
    ``memory_pressure(trace)`` replays it through the
    ``serve.governor._os_pressure`` seam, driving the governor's
    reclaim/regrow ladder exactly as a real OS watermark would.
  * **Residency faults** — ``fetch_fault(times, delay_s)`` breaks (or,
    with a delay, slows) ``serve.residency._transfer``, the host→HBM
    expert-fetch seam of the tiered-residency cache; a persistent fault
    turns every cache miss into a ladder-walked refusal, proving a
    miss-storm can never hang the scheduler.

Seeded via ``REPRO_FAULT_SEED`` (CI's fault-injection job varies it) so
bit positions differ across runs without losing reproducibility.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import os
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _default_seed() -> int:
    return int(os.environ.get("REPRO_FAULT_SEED", "0"))


class FaultProbe:
    """Execution-count handle yielded by the injection context managers.

    ``executions`` is the number of guarded calls observed so far; tests
    use a never-firing probe (``nth`` huge) on a clean run to calibrate a
    fault-at-step-N injection for a later faulty run of the same trace.
    """

    def __init__(self):
        self.executions = 0


PRESSURE_KINDS = ("step", "spike", "ramp", "oscillate")


def pressure_trace(kind: str, *, boot_bytes: int, low_bytes: int,
                   n_steps: int, period: int = 8,
                   seed: Optional[int] = None) -> list:
    """A seeded per-step HBM-budget trace (bytes), one value per engine
    step — the pressure shapes a shared-memory edge device actually sees:

      * 'step'       — budget drops to ``low_bytes`` at a seeded step and
                       stays there (the OS claimed pages for good);
      * 'spike'      — a short seeded window at ``low_bytes``, then full
                       recovery (a co-tenant app launch);
      * 'ramp'       — linear descent to ``low_bytes`` over the first
                       half, linear recovery over the second (background
                       compaction / thermal backoff);
      * 'oscillate'  — square wave between the two levels with period
                       ``period`` and a seeded phase (the thrash trace:
                       hysteresis must keep the plan-change count bounded
                       by band crossings, not steps).

    Seeded from ``REPRO_FAULT_SEED`` by default so CI varies the timing
    without losing reproducibility.
    """
    if kind not in PRESSURE_KINDS:
        raise ValueError(f"kind must be one of {PRESSURE_KINDS}, "
                         f"got {kind!r}")
    rng = np.random.default_rng(_default_seed() if seed is None else seed)
    boot, low, n = int(boot_bytes), int(low_bytes), int(n_steps)
    t = np.arange(n)
    if kind == "step":
        at = int(rng.integers(1, max(2, n // 4)))
        vals = np.where(t < at, boot, low)
    elif kind == "spike":
        width = max(1, period // 2)
        at = int(rng.integers(1, max(2, n - width)))
        vals = np.where((t >= at) & (t < at + width), low, boot)
    elif kind == "ramp":
        half = max(1, n // 2)
        vals = np.concatenate([
            np.linspace(boot, low, half),
            np.linspace(low, boot, n - half)]).astype(np.int64)
    else:                                              # oscillate
        phase = int(rng.integers(max(1, period)))
        vals = np.where(((t + phase) // max(1, period)) % 2 == 0,
                        boot, low)
    return [int(v) for v in vals]


class FaultInjector:
    def __init__(self, seed: Optional[int] = None):
        self.rng = np.random.default_rng(
            _default_seed() if seed is None else seed)

    # -- artifact corruption -------------------------------------------
    def _flip(self, arr, bit: Optional[int]) -> jax.Array:
        a = np.asarray(jax.device_get(arr)).copy()
        raw = a.reshape(-1).view(np.uint8)
        if raw.size == 0:
            raise ValueError("cannot flip a bit in an empty plane")
        b = int(self.rng.integers(raw.size * 8)) if bit is None else bit
        raw[b // 8] ^= np.uint8(1 << (b % 8))
        return jnp.asarray(a)

    def flip_bit(self, state, leaf_substr: str, plane: str = "codes",
                 bit: Optional[int] = None):
        """Return a copy of ``state`` with one bit flipped in the first
        plane whose keyed path contains ``leaf_substr`` and ends in
        ``plane`` ('codes'|'literals'|'nlit'|'scale'|'zero'|'codes_t'|…).
        The manifest is deliberately NOT rebuilt — that is the point."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(state.params)
        leaves = [leaf for _, leaf in flat]
        target = None
        for i, (path, leaf) in enumerate(flat):
            name = jax.tree_util.keystr(path)
            if leaf_substr in name and name.rsplit(".", 1)[-1] == plane:
                target = (i, name)
                break
        if target is None:
            raise KeyError(f"no leaf matching {leaf_substr!r} plane "
                           f"{plane!r} in params")
        i, name = target
        leaves[i] = self._flip(leaves[i], bit)
        new = dataclasses.replace(state,
                                  params=treedef.unflatten(leaves))
        return new, name

    def flip_lut_bit(self, state, bit: Optional[int] = None):
        """Flip one bit in the model-wide decode LUT."""
        if state.lut is None:
            raise ValueError("state has no LUT")
        return dataclasses.replace(state, lut=self._flip(state.lut, bit))

    # -- checkpoint damage ---------------------------------------------
    @staticmethod
    def _step_dir(ckpt_dir: str, step: int) -> str:
        return os.path.join(ckpt_dir, f"step_{step:08d}")

    def uncommit_step(self, ckpt_dir: str, step: int):
        """Torn write: the COMMIT marker never landed."""
        os.remove(os.path.join(self._step_dir(ckpt_dir, step), "COMMIT"))

    def truncate_step(self, ckpt_dir: str, step: int, keep_bytes: int = 64):
        """Chop every shard file to ``keep_bytes`` (unreadable archive)."""
        d = self._step_dir(ckpt_dir, step)
        for fn in os.listdir(d):
            if fn.startswith("shard_"):
                path = os.path.join(d, fn)
                with open(path, "r+b") as f:
                    f.truncate(keep_bytes)

    def corrupt_step(self, ckpt_dir: str, step: int, nbits: int = 8):
        """Post-commit bit rot inside the shard payload (readable archive,
        wrong bytes — only checksums catch this)."""
        d = self._step_dir(ckpt_dir, step)
        for fn in sorted(os.listdir(d)):
            if fn.startswith("shard_"):
                path = os.path.join(d, fn)
                data = bytearray(open(path, "rb").read())
                # flip bits in the back half: past the zip directory-ish
                # header region, inside the stored arrays
                lo = len(data) // 2
                for _ in range(nbits):
                    b = int(self.rng.integers(lo * 8, len(data) * 8))
                    data[b // 8] ^= 1 << (b % 8)
                open(path, "wb").write(bytes(data))
                return

    # -- runtime errors ------------------------------------------------
    def failing(self, fn: Callable, times: int = 1,
                message: str = "injected device fault") -> Callable:
        """Wrap ``fn`` to raise ``JaxRuntimeError`` on its first ``times``
        calls, then delegate — the transient-fault model at a call seam."""
        counter = itertools.count()

        def wrapped(*args: Any, **kw: Any):
            if next(counter) < times:
                raise jax.errors.JaxRuntimeError(message)
            return fn(*args, **kw)

        return wrapped

    @contextlib.contextmanager
    def decode_fault(self, nth: int = 1, times: int = 1 << 30,
                     message: str = "injected decode fault"):
        """Arm a real in-graph fault on the Nth compressed-matmul execution.

        Patches ``ops.decode_dequant_matmul`` with a wrapper that threads
        an ordered ``io_callback`` tick into the graph; the host counter
        raises for executions [nth, nth + times), which surfaces as a
        ``JaxRuntimeError`` out of the jitted program (including from
        inside the decode ``lax.scan``).  Traces made while the session
        impl lever pins 'unfused'/'materialize' are left clean, so the
        degradation ladder's fallback rungs recover.  NOTES: (1) callers
        must trace under a fresh config name — already-cached jits don't
        carry the injected callback; (2) this models a *persistent* fused-
        kernel fault: the error lives on the ordered-effects token, and a
        later healthy program overwrites that token, so a fault that stops
        firing mid-request can be masked — model *transient* faults with
        :meth:`failing` at the request seam instead.
        """
        from repro.kernels import ops

        orig = ops.decode_dequant_matmul
        count = itertools.count(1)
        probe = FaultProbe()

        def host_tick():
            n = next(count)
            probe.executions = n
            if nth <= n < nth + times:
                raise RuntimeError(f"{message} (execution {n})")
            return np.int32(0)

        def wrapped(x, packed, lut, **kw):
            if ops._DEFAULT_IMPL in ("unfused", "materialize"):
                return orig(x, packed, lut, **kw)
            tick = jax.experimental.io_callback(
                host_tick, jax.ShapeDtypeStruct((), jnp.int32), ordered=True)
            # Real (non-foldable) data dependency on the callback *result*
            # buffer: the tick is always 0, but XLA can't prove it, so the
            # activations inherit the callback's definition event — when
            # host_tick raises, the poisoned event propagates to the rung's
            # outputs and block_until_ready raises JaxRuntimeError.  (A
            # ``tick * 0`` dependency gets constant-folded away; the error
            # then lives only on the ordered-effects token, which is not
            # awaited until interpreter exit.)
            x = x + jnp.minimum(tick, 0).astype(x.dtype)
            return orig(x, packed, lut, **kw)

        ops.decode_dequant_matmul = wrapped
        try:
            yield probe
        finally:
            ops.decode_dequant_matmul = orig
            # Drain the poisoned ordered-effects token: the injected raise
            # also fails the token buffer, and jax awaits those at atexit —
            # an undrained one would crash the *interpreter exit* of an
            # otherwise-green test run.
            from jax._src import dispatch as _dispatch
            try:
                _dispatch.runtime_tokens.block_until_ready()
            except Exception:
                pass
            _dispatch.runtime_tokens.clear()

    # -- memory pressure -----------------------------------------------
    @contextlib.contextmanager
    def memory_pressure(self, trace, *, hold_last: bool = True):
        """Replay a budget trace through ``serve.governor._os_pressure``.

        Each governor poll (one per engine step) consumes the next value
        of ``trace`` (bytes); past the end the last value holds (the
        pressure persists) unless ``hold_last=False``, after which the
        seam reports no signal.  Yields a :class:`FaultProbe` whose
        ``executions`` counts the polls served — tests use it to assert
        the trace actually drove the steps they measured.
        """
        from repro.serve import governor as _gov

        orig = _gov._os_pressure
        probe = FaultProbe()
        seq = [int(v) for v in trace]

        def patched():
            i = probe.executions
            probe.executions += 1
            if i < len(seq):
                return seq[i]
            return seq[-1] if (hold_last and seq) else None

        _gov._os_pressure = patched
        try:
            yield probe
        finally:
            _gov._os_pressure = orig

    # -- residency faults ----------------------------------------------
    @contextlib.contextmanager
    def fetch_fault(self, times: int = 1, delay_s: float = 0.0,
                    message: str = "injected fetch fault"):
        """Break or slow the host→HBM expert transfer link.

        Patches ``serve.residency._transfer`` — the one seam every demand
        fetch and prefetch crosses — to raise ``JaxRuntimeError`` for its
        first ``times`` crossings (or, with ``delay_s`` > 0, to sleep
        before delegating: a saturated link rather than a dead one).
        Demand-fetch faults propagate out of ``ResidencyManager.run`` and
        walk the degradation ladder like any device fault; prefetch-
        worker faults are swallowed into ``prefetch_error`` counts and
        re-surface as later demand misses.  A miss-storm under a
        persistent fault (``times`` huge) must therefore end as refused
        requests via ladder exhaustion/quarantine — never a hang.  Yields
        a :class:`FaultProbe` counting the injected crossings.
        """
        from repro.serve import residency as _res

        orig = _res._transfer
        counter = itertools.count()
        probe = FaultProbe()

        def wrapped(arrays):
            n = next(counter)
            if n < times:
                probe.executions += 1
                if delay_s > 0:
                    time.sleep(delay_s)
                    return orig(arrays)
                raise jax.errors.JaxRuntimeError(
                    f"{message} (transfer {n + 1} of {times})")
            return orig(arrays)

        _res._transfer = wrapped
        try:
            yield probe
        finally:
            _res._transfer = orig

    # -- scheduler faults ----------------------------------------------
    @contextlib.contextmanager
    def slot_fault(self, slot: int, nth: int = 1, times: int = 1 << 30,
                   message: str = "injected poisoned-request fault"):
        """Arm a poisoned-request fault against one decode slot.

        Patches ``serve.scheduler._generate_step`` with a wrapper that
        raises ``JaxRuntimeError`` whenever the target ``slot`` is active
        in the step's mask — from the ``nth`` such call, for ``times``
        calls.  The fault *follows the request*: it fires on every
        degradation-ladder rung (unlike :meth:`decode_fault`, which spares
        the fallback impls), so the ladder cannot recover and the
        scheduler's quarantine bisect is the only way out.  The bisect's
        masked replays see the same wrapper — sub-batches that exclude the
        slot run clean, the culprit singleton keeps faulting — which is
        exactly the group-testing signal the bisection needs.  Yields a
        :class:`FaultProbe` counting the slot's guarded calls
        (fault-at-step-N: pick ``nth`` > 1 to poison a request only after
        it has decoded N-1 healthy steps mid-batch).
        """
        from repro.serve import scheduler as _sched

        orig = _sched._generate_step
        count = itertools.count(1)
        probe = FaultProbe()

        def wrapped(cfg, mesh, page_size, params, lut, pages, page_table,
                    tok, pos, active, temp, keys):
            if bool(np.asarray(active)[slot]):
                n = next(count)
                probe.executions = n
                if nth <= n < nth + times:
                    raise jax.errors.JaxRuntimeError(
                        f"{message} (slot {slot}, active call {n})")
            return orig(cfg, mesh, page_size, params, lut, pages,
                        page_table, tok, pos, active, temp, keys)

        _sched._generate_step = wrapped
        try:
            yield probe
        finally:
            _sched._generate_step = orig

    @contextlib.contextmanager
    def alloc_failure(self, times: int = 1, seam: str = "can_alloc"):
        """Inject page-pool exhaustion for the next ``times`` admissions.

        seam='can_alloc' (default) makes ``PagedKVPool.can_alloc`` report
        False — the scheduler sees pressure *before* prefilling and walks
        its preempt-or-wait path.  seam='alloc' leaves ``can_alloc``
        truthful but makes ``alloc`` itself raise ``PoolExhausted`` — the
        post-prefill requeue path (a raced reclaim).  Yields a
        :class:`FaultProbe` counting the injected failures.
        """
        if seam not in ("can_alloc", "alloc"):
            raise ValueError(f"seam must be 'can_alloc' or 'alloc', "
                             f"got {seam!r}")
        from repro.serve import kv_cache as _kv

        probe = FaultProbe()
        counter = itertools.count()
        if seam == "can_alloc":
            orig = _kv.PagedKVPool.can_alloc

            def fake_can_alloc(pool):
                if next(counter) < times:
                    probe.executions += 1
                    return False
                return orig(pool)

            _kv.PagedKVPool.can_alloc = fake_can_alloc
            try:
                yield probe
            finally:
                _kv.PagedKVPool.can_alloc = orig
        else:
            orig = _kv.PagedKVPool.alloc

            def fake_alloc(pool, slot):
                if next(counter) < times:
                    probe.executions += 1
                    raise _kv.PoolExhausted(
                        f"injected alloc failure ({probe.executions} of "
                        f"{times})")
                return orig(pool, slot)

            _kv.PagedKVPool.alloc = fake_alloc
            try:
                yield probe
            finally:
                _kv.PagedKVPool.alloc = orig
