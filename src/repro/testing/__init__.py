"""Test-support utilities importable by tests, benchmarks, and CI jobs."""
from .faults import FaultInjector

__all__ = ["FaultInjector"]
