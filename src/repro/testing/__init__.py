"""Test-support utilities importable by tests, benchmarks, and CI jobs."""
from .faults import (FaultInjector, FaultProbe, PRESSURE_KINDS,
                     pressure_trace)

__all__ = ["FaultInjector", "FaultProbe", "PRESSURE_KINDS",
           "pressure_trace"]
