"""Test-support utilities importable by tests, benchmarks, and CI jobs."""
from .faults import FaultInjector, FaultProbe

__all__ = ["FaultInjector", "FaultProbe"]
