"""The paper's own models: Llama-3.2-1B and -3B (Tiny-QMoE Tables 1-4).

[arXiv:2407.21783 (Llama 3 herd) + meta-llama/Llama-3.2 cards; hf]
1B: 16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256, tied.
3B: 28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256, tied.
These anchor the paper-fidelity benchmarks (compression ratio table).
"""
from repro.models.config import ModelConfig
from .base import ArchEntry, register

FULL_1B = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
    vocab_size=128256, head_dim=64, rope_theta=500_000.0,
    tie_embeddings=True,
)

FULL_3B = ModelConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=8192,
    vocab_size=128256, head_dim=128, rope_theta=500_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="llama3.2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=211, head_dim=16, tie_embeddings=True, remat=False,
)

ENTRY_1B = register(ArchEntry(
    arch_id="llama3.2-1b", full=FULL_1B, smoke=SMOKE,
    source="meta-llama/Llama-3.2-1B; hf",
    notes="paper's primary subject (Tables 1-4).",
))
ENTRY_3B = register(ArchEntry(
    arch_id="llama3.2-3b", full=FULL_3B, smoke=SMOKE,
    source="meta-llama/Llama-3.2-3B; hf",
    notes="paper's secondary subject (Tables 1-4).",
))
