"""deepseek-v2-lite-16b — MLA + fine-grained MoE.

[arXiv:2405.04434; hf] 27L d_model=2048 16H d_ff(expert)=1408
vocab=102400, MoE 64 routed top-6 + 2 shared, MLA kv_lora=512
(q_lora none in Lite), qk_nope 128 / qk_rope 64 / v 128; first layer
dense FFN (10944).  The assignment bracket's "160 routed" refers to the
non-Lite V2; Lite's checkpoint has 64 routed experts — we follow the
model card + the assignment's "MoE 64e top-6".
"""
from repro.models.config import ModelConfig
from .base import ArchEntry, register

FULL = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944, vocab_size=102400,
    n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
    first_dense_layers=1,
    mla=True, kv_lora_rank=512, q_lora_rank=0,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=192,
    vocab_size=211, n_experts=8, n_shared_experts=2, top_k=2,
    moe_d_ff=48, first_dense_layers=1,
    mla=True, kv_lora_rank=32, q_lora_rank=0,
    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16, remat=False,
)

ENTRY = register(ArchEntry(
    arch_id="deepseek-v2-lite-16b", full=FULL, smoke=SMOKE,
    source="arXiv:2405.04434; hf",
    notes="closest to original QMoE setting: expert FFNs dominate bytes "
          "and are cold per token; long_500k skipped (quadratic).",
))
