"""internvl2-2b — InternViT frontend (STUB) + InternLM2-1.8B backbone.

[arXiv:2404.16821; hf] 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553.  Vision frontend provides precomputed patch embeddings
(256 tokens/image) per the assignment.
"""
from repro.models.config import ModelConfig
from .base import ArchEntry, register

FULL = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
    vocab_size=92553, head_dim=128, rope_theta=1_000_000.0,
    frontend="vision", n_patches=256,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=211, head_dim=16, frontend="vision", n_patches=8,
    remat=False,
)

ENTRY = register(ArchEntry(
    arch_id="internvl2-2b", full=FULL, smoke=SMOKE,
    source="arXiv:2404.16821; hf",
    notes="text+image prefill; decode is text-only; long_500k skipped "
          "(quadratic).",
))
