"""Config registry scaffolding shared by all architecture files."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    full: ModelConfig
    smoke: ModelConfig
    source: str               # citation + verification tier from assignment
    notes: str = ""


_REGISTRY: dict[str, ArchEntry] = {}


def register(entry: ArchEntry) -> ArchEntry:
    _REGISTRY[entry.arch_id] = entry
    return entry


def get(arch_id: str) -> ArchEntry:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def all_archs() -> list[str]:
    return sorted(_REGISTRY)
