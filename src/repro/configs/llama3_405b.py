"""llama3-405b — the capacity showcase for Tiny-QMoE serving.

[arXiv:2407.21783; unverified] 126L d_model=16384 128H (GQA kv=8)
d_ff=53248 vocab=128256, head_dim=128, rope 5e5.
"""
from repro.models.config import ModelConfig
from .base import ArchEntry, register

FULL = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_ff=53248,
    vocab_size=128256, head_dim=128, rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="llama3-405b-smoke", family="dense",
    n_layers=3, d_model=128, n_heads=8, n_kv_heads=2, d_ff=320,
    vocab_size=211, head_dim=16, remat=False,
)

ENTRY = register(ArchEntry(
    arch_id="llama3-405b", full=FULL, smoke=SMOKE,
    source="arXiv:2407.21783; unverified",
    notes="int8+dict compression is what fits 405B on serving meshes; "
          "long_500k skipped (quadratic).",
))
