"""qwen2-7b — dense GQA with QKV bias.

[arXiv:2407.10671; hf] 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064, head_dim=128, qkv_bias.
"""
from repro.models.config import ModelConfig
from .base import ArchEntry, register

FULL = ModelConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab_size=152064, head_dim=128, qkv_bias=True, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=176,
    vocab_size=211, head_dim=16, qkv_bias=True, remat=False,
)

ENTRY = register(ArchEntry(
    arch_id="qwen2-7b", full=FULL, smoke=SMOKE,
    source="arXiv:2407.10671; hf",
    notes="long_500k skipped (quadratic).",
))
