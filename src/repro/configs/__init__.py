"""Architecture registry — 10 assigned archs + the paper's Llama-3.2 pair.

``get_config(arch_id)`` returns the registered ArchEntry with exact
published hyperparameters (FULL) and a reduced same-family SMOKE config.
"""
from .base import ArchEntry, get, all_archs, register

# Import for registration side effects.
from . import (seamless_m4t_medium, mamba2_2_7b, qwen3_4b, llama3_405b,
               internlm2_1_8b, qwen2_7b, deepseek_v2_lite_16b,
               kimi_k2_1t_a32b, internvl2_2b, zamba2_1_2b, llama32_paper)

ASSIGNED_ARCHS = [
    "seamless-m4t-medium", "mamba2-2.7b", "qwen3-4b", "llama3-405b",
    "internlm2-1.8b", "qwen2-7b", "deepseek-v2-lite-16b",
    "kimi-k2-1t-a32b", "internvl2-2b", "zamba2-1.2b",
]
PAPER_ARCHS = ["llama3.2-1b", "llama3.2-3b"]


def get_config(arch_id: str) -> ArchEntry:
    return get(arch_id)


__all__ = ["ArchEntry", "get_config", "all_archs", "ASSIGNED_ARCHS",
           "PAPER_ARCHS"]
