"""internlm2-1.8b — dense GQA.

[arXiv:2403.17297; hf] 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544, head_dim=128.
"""
from repro.models.config import ModelConfig
from .base import ArchEntry, register

FULL = ModelConfig(
    name="internlm2-1.8b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
    vocab_size=92544, head_dim=128, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="internlm2-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=211, head_dim=16, remat=False,
)

ENTRY = register(ArchEntry(
    arch_id="internlm2-1.8b", full=FULL, smoke=SMOKE,
    source="arXiv:2403.17297; hf",
    notes="long_500k skipped (quadratic).",
))
