"""zamba2-1.2b — Mamba2 backbone + shared attention block.

[arXiv:2411.15242; hf] 38L d_model=2048 32H (GQA kv=32 → MHA) d_ff=8192
vocab=32000, ssm_state=64.  One shared attn+MLP block applied every 6
mamba blocks (weights shared, separate KV cache per application).
Sub-quadratic backbone: runs long_500k (attn blocks decode O(L) per step).
"""
from repro.models.config import ModelConfig
from .base import ArchEntry, register

FULL = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000, head_dim=64, ssm_state=64, ssm_head_dim=64,
    ssm_expand=2, ssm_n_groups=1, ssm_chunk=256, attn_period=6,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=211, head_dim=16, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=8, attn_period=2, remat=False,
)

ENTRY = register(ArchEntry(
    arch_id="zamba2-1.2b", full=FULL, smoke=SMOKE,
    source="arXiv:2411.15242; hf",
    notes="SSD params dense; shared attn block weights compress once, "
          "used 6x.",
))
