"""seamless-m4t-medium — enc-dec multimodal (audio) backbone.

[arXiv:2308.11596; hf] 12L d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206.  "12L" is read as 12 encoder + 12 decoder layers (the
HF checkpoint's speech-enc/text-dec depths); audio frontend is a STUB —
input_specs provides precomputed frame embeddings (B, S, d).
"""
from repro.models.config import ModelConfig
from .base import ArchEntry, register

FULL = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=24, encoder_layers=12, decoder_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=256206, head_dim=64, rope_theta=10_000.0,
    frontend="audio",
)

SMOKE = ModelConfig(
    name="seamless-m4t-medium-smoke", family="encdec",
    n_layers=4, encoder_layers=2, decoder_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=211, head_dim=16, frontend="audio", remat=False,
)

ENTRY = register(ArchEntry(
    arch_id="seamless-m4t-medium", full=FULL, smoke=SMOKE,
    source="arXiv:2308.11596; hf",
    notes="enc-dec; decode shapes exercise the text decoder with cached "
          "encoder K/V; long_500k skipped (quadratic cross+self attn).",
))
