"""kimi-k2-1t-a32b — trillion-param MoE (paper-table config).

[arXiv:2501.kimi2; unverified] 61L d_model=7168 64H (GQA kv=8)
d_ff(expert)=2048 vocab=163840, MoE 384 routed top-8 (+1 shared),
first layer dense (d_ff 18432), head_dim 128.  NOTE: the real K2 uses
MLA; the assigned table pins GQA kv=8, so we follow the assignment
(DESIGN.md records the deviation).
"""
from repro.models.config import ModelConfig
from .base import ArchEntry, register

FULL = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=18432, vocab_size=163840, head_dim=128,
    n_experts=384, n_shared_experts=1, top_k=8, moe_d_ff=2048,
    first_dense_layers=1, capacity_factor=1.25,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="kimi-k2-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192,
    vocab_size=211, head_dim=16, n_experts=8, n_shared_experts=1,
    top_k=2, moe_d_ff=48, first_dense_layers=1, remat=False,
)

ENTRY = register(ArchEntry(
    arch_id="kimi-k2-1t-a32b", full=FULL, smoke=SMOKE,
    source="arXiv:2501.kimi2; unverified",
    notes="1T total / ~32B active; EP shards experts on the model axis; "
          "long_500k skipped (quadratic).",
))
