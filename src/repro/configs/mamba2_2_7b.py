"""mamba2-2.7b — attention-free SSD (state-space duality) LM.

[arXiv:2405.21060; unverified] 64L d_model=2560 d_ff=0 vocab=50280,
ssm_state=128.  head_dim 64, expand 2 → d_inner 5120, 80 heads, 1 group.
Sub-quadratic: runs long_500k.
"""
from repro.models.config import ModelConfig
from .base import ArchEntry, register

FULL = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    ssm_n_groups=1, ssm_conv=4, ssm_chunk=256, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=3, d_model=64, d_ff=0, vocab_size=211,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8,
    tie_embeddings=True, remat=False,
)

ENTRY = register(ArchEntry(
    arch_id="mamba2-2.7b", full=FULL, smoke=SMOKE,
    source="arXiv:2405.21060; unverified",
    notes="SSD recurrence params excluded from quant+compress "
          "(DESIGN.md §Arch-applicability); in/out projections compress.",
))
