"""qwen3-4b — dense GQA with qk_norm.

[hf:Qwen/Qwen3-8B; hf] 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936, head_dim=128, qk_norm.
"""
from repro.models.config import ModelConfig
from .base import ArchEntry, register

FULL = ModelConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, d_ff=9728,
    vocab_size=151936, head_dim=128, qk_norm=True, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=211, head_dim=16, qk_norm=True, remat=False,
)

ENTRY = register(ArchEntry(
    arch_id="qwen3-4b", full=FULL, smoke=SMOKE,
    source="hf:Qwen/Qwen3-8B; hf",
    notes="long_500k skipped (full quadratic attention).",
))
