"""Distribution: partition rules for params/caches/data over (pod, data, model)."""
from .partition import (ShardingConfig, make_param_specs, make_cache_specs,
                        make_data_specs, to_named)

__all__ = ["ShardingConfig", "make_param_specs", "make_cache_specs",
           "make_data_specs", "to_named"]
