"""Partition rules — param path → PartitionSpec, divisibility-guarded.

Scheme (DESIGN.md §5):
  * TP ("model"): attention head dims, FFN hidden, experts (EP), vocab.
  * FSDP ("data"): the non-TP weight dim, train mode (ZeRO-3 style) or
    serve mode with ``fsdp_weights=True`` for models too big for TP alone.
  * "pod": extends the data axis across pods (hierarchical DP).

Every rule is applied only if the dim divides the axis size — otherwise
that dim silently replicates (e.g. kv-heads=8 < model=16).  This keeps one
rule table valid across all 12 architectures and both meshes.

Compressed containers: the blocked codec's block axis follows the dense
weight's *leading* (out) dim, so codes/literals/nlit shard on "model"
exactly when the dense weight's out dim would (encode is block-aligned,
see blocked_codec.shard_aligned_block_weights).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import AXIS_DATA, AXIS_MODEL, AXIS_POD


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    mode: str = "train"            # train | serve
    fsdp_weights: bool = True      # shard non-TP weight dim on data axis
    shard_embed_vocab: bool = True
    # serve-only: also use the pod axis for FSDP weight sharding
    pod_in_fsdp: bool = True


# Rule table: (path regex, spec builder). Specs are written for the
# *unstacked* weight; a leading None is prepended per stacked dim.
# 'M' = model/TP axis, 'F' = fsdp(data) axis placeholder.
_RULES: list[tuple[str, tuple]] = [
    # --- attention ---------------------------------------------------------
    (r"attn/(wq|wk|wv)$",        ("M", "F")),
    (r"attn/(bq|bk|bv)$",        ("M",)),
    (r"attn/wo$",                ("F", "M")),
    (r"attn/(q_norm|k_norm)$",   (None,)),
    # --- MLA ---------------------------------------------------------------
    (r"attn/wq_a$",              (None, "F")),
    (r"attn/wq_b$",              ("M", None)),
    (r"attn/wkv_a$",             (None, "F")),
    (r"attn/wkv_b$",             ("M", None)),
    (r"attn/(q_a_norm|kv_a_norm)$", (None,)),
    # --- cross attention (same shapes as attn) ------------------------------
    (r"cross/(wq|wk|wv)$",       ("M", "F")),
    (r"cross/wo$",               ("F", "M")),
    # --- dense FFN -----------------------------------------------------------
    (r"mlp/(w_gate|w_up)$",      ("M", "F")),
    (r"mlp/w_down$",             ("F", "M")),
    (r"shared/(w_gate|w_up)$",   ("M", "F")),
    (r"shared/w_down$",          ("F", "M")),
    # --- MoE -----------------------------------------------------------------
    (r"moe/router$",             (None, None)),
    (r"experts/(w_gate|w_up)$",  ("M", None, "F")),   # (E, ffe, d): EP on E
    (r"experts/w_down$",         ("M", None, "F")),   # (E, d, ffe)
    # --- mamba2 ---------------------------------------------------------------
    (r"mamba/in_proj$",          ("M", "F")),
    (r"mamba/out_proj$",         ("F", "M")),
    (r"mamba/conv_w$",           ("M", None)),
    (r"mamba/conv_b$",           ("M",)),
    (r"mamba/(a_log|dt_bias|d_skip)$", (None,)),
    (r"mamba/gate_norm$",        (None,)),
    # --- embeddings / head ------------------------------------------------------
    (r"(embed|dec_embed|lm_head)$", ("V", "F")),
    # --- norms -------------------------------------------------------------------
    (r"norm$",                   (None,)),
]


def _leaf_path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(k.name)
        else:
            parts.append(str(k))
    return "/".join(parts)


def _resolve_axis(tag, scfg: ShardingConfig, mesh_axes: tuple):
    if tag is None:
        return None
    if tag == "M":
        return AXIS_MODEL if AXIS_MODEL in mesh_axes else None
    if tag == "V":  # vocab: TP on model
        return AXIS_MODEL if AXIS_MODEL in mesh_axes else None
    if tag == "F":
        if not scfg.fsdp_weights:
            return None
        axes = []
        if scfg.mode == "train" or scfg.pod_in_fsdp:
            if AXIS_POD in mesh_axes:
                axes.append(AXIS_POD)
        if AXIS_DATA in mesh_axes:
            axes.append(AXIS_DATA)
        # collapse singletons to the bare axis name: P('data') and
        # P(('data',)) mean the same sharding but do not compare
        # equal, and specs built here are compared against
        # bare-name specs (tests, spec plumbing)
        if len(axes) == 1:
            return axes[0]
        return tuple(axes) if axes else None
    raise ValueError(tag)


def _axis_total(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _guarded_spec(dims: tuple, shape: tuple, mesh: Mesh) -> P:
    """Drop any axis the dim doesn't divide; trim/extend to rank."""
    spec = []
    for i, d in enumerate(shape):
        axis = dims[i] if i < len(dims) else None
        if axis is not None and (d == 0 or d % _axis_total(mesh, axis) != 0):
            axis = None
        spec.append(axis)
    return P(*spec)


def _spec_for_dense(path_str: str, shape: tuple, scfg: ShardingConfig,
                    mesh: Mesh, stacked: int) -> P:
    for pat, tags in _RULES:
        if re.search(pat, path_str):
            dims = tuple(_resolve_axis(t, scfg, mesh.axis_names)
                         for t in tags)
            dims = (None,) * stacked + dims
            return _guarded_spec(dims, shape, mesh)
    return _guarded_spec((), shape, mesh)  # replicate unknowns


# Container plane handling: PackedLinear/QuantLinear/TiledPackedLinear.
_PLANE_SUFFIX = re.compile(
    r"/(values|codes_t|literals_t|nlit_t|codes|literals|nlit|scale|zero)$")


def _spec_for_plane(path_str: str, plane: str, shape: tuple,
                    scfg: ShardingConfig, mesh: Mesh) -> P:
    """Compressed planes shard along their leading (out-block) axis exactly
    when the dense weight's out dim is TP-sharded.  With ``fsdp_weights``
    the data/pod axes stack onto the same block axis (codec blocks have no
    second weight dim to FSDP separately): a 405B model's planes then live
    /256, gathered per layer like any FSDP param."""
    base = _PLANE_SUFFIX.sub("", path_str)
    for pat, tags in _RULES:
        if re.search(pat, base):
            # NOTE(§Perf DP2, refuted): sharding expert planes on the
            # (stacked) E dim instead of the block axis aligns decoded
            # experts with the (E:model) dispatch, but removes the FSDP
            # block sharding that 1T-scale MoE needs — kimi prefill blew
            # 49.6 → 91.2 GiB/dev.  Block-axis sharding retained.
            out_tag = tags[0]   # dense out-dim tag drives everything
            axis = _resolve_axis(out_tag, scfg, mesh.axis_names)
            fsdp = _resolve_axis("F", scfg, mesh.axis_names)
            stacked = len(shape) - _plane_rank(plane)
            if stacked and re.search(r"experts/", base) and plane in (
                    "codes", "literals", "nlit", "scale", "zero"):
                # Grouped fused MoE (PR 3): expert planes store
                # expert-major — the stacked E dim on model — so the
                # grouped shard_map's in_specs (experts on the model axis)
                # match storage and no plane bytes move at use time; the
                # block axis keeps the FSDP axes for 1T-scale stacks.
                # Unlike §Perf DP2's refuted E-instead-of-blocks variant,
                # both shardings hold at once here.
                m_axis = (AXIS_MODEL if AXIS_MODEL in mesh.axis_names
                          else None)
                blk = fsdp if plane in ("codes", "literals", "nlit") \
                    else None
                dims = ((None,) * (stacked - 1) + (m_axis, blk) +
                        (None,) * (_plane_rank(plane) - 1))
                return _guarded_spec(dims, shape, mesh)
            if plane in ("codes_t", "literals_t", "nlit_t"):
                # 2D tiles: tile axis on data, block axis on model —
                # weights permanently resident, zero use-time collectives.
                # Across pods weights REPLICATE (production choice: DCN is
                # too slow to stream weights; pods carry batch).
                m_axis = (AXIS_MODEL if AXIS_MODEL in mesh.axis_names
                          else None)
                d_axis = AXIS_DATA if AXIS_DATA in mesh.axis_names else None
                dims = ((None,) * stacked + (d_axis, m_axis) +
                        (None,) * (_plane_rank(plane) - 2))
                return _guarded_spec(dims, shape, mesh)
            if plane in ("codes", "literals", "nlit") and fsdp is not None:
                parts = list(fsdp if isinstance(fsdp, tuple) else (fsdp,))
                for a in (axis if isinstance(axis, tuple)
                          else (axis,) if axis else ()):
                    if a not in parts:       # wo/w_down have out_tag == F
                        parts.append(a)
                axis = tuple(parts)
            # rank layout: [stacked...] + plane dims; shard 1st plane dim.
            dims = (None,) * stacked + (axis,) + (None,) * (
                _plane_rank(plane) - 1)
            return _guarded_spec(dims, shape, mesh)
    return _guarded_spec((), shape, mesh)


def _plane_rank(plane: str) -> int:
    return {"values": 2, "codes": 2, "literals": 3, "nlit": 1,
            "scale": 2, "zero": 2,
            "codes_t": 3, "literals_t": 4, "nlit_t": 2}[plane]


def clean_keystr(name: str) -> str:
    """jax keystr "['blocks']['mlp']['w_down']" -> "blocks/mlp/w_down"."""
    return re.sub(r"[\[\]']+", "/", name).strip("/")


def is_row_parallel(path_str: str) -> bool:
    """True for weights whose matmul contracts the model-sharded dim
    (wo / w_down: tags ("F", "M")) — their compressed planes decode to
    row-sharded layout, and the consumer must reshard the decoded weight,
    not the activations (§Perf P2)."""
    for pat, tags in _RULES:
        if re.search(pat, path_str):
            return len(tags) >= 2 and tags[0] == "F" and tags[1] == "M"
    return False


def make_param_specs(params: Any, mesh: Mesh,
                     scfg: ShardingConfig | None = None,
                     stacked_detector=None) -> Any:
    """PartitionSpec tree matching ``params`` (arrays or ShapeDtypeStructs).

    Stacked (scanned) leading dims are detected by comparing leaf rank to
    the rule's expected rank; anything extra on the left replicates.
    """
    scfg = scfg or ShardingConfig()

    def one(path, leaf):
        path_str = _leaf_path_str(path)
        shape = tuple(leaf.shape)
        m = _PLANE_SUFFIX.search(path_str)
        if m:
            return _spec_for_plane(path_str, m.group(1), shape, scfg, mesh)
        # dense leaf: infer stacked dims from rule rank
        for pat, tags in _RULES:
            if re.search(pat, path_str):
                stacked = max(0, len(shape) - len(tags))
                return _spec_for_dense(path_str, shape, scfg, mesh, stacked)
        return _guarded_spec((), shape, mesh)

    return jax.tree_util.tree_map_with_path(one, params)


def make_cache_specs(caches: Any, mesh: Mesh, batch_axis=None) -> Any:
    """KV/SSM cache specs: batch on data axes when divisible, heads/state
    dims on model when divisible."""
    batch_axes = batch_axis if batch_axis is not None else (
        tuple(a for a in (AXIS_POD, AXIS_DATA) if a in mesh.axis_names)
        or None)

    msize = mesh.shape[AXIS_MODEL] if AXIS_MODEL in mesh.axis_names else 1

    def one(path, leaf):
        path_str = _leaf_path_str(path)
        shape = tuple(leaf.shape)
        # stacked layer dim first for 'blocks' caches and enc-dec cross K/V
        stacked = 1 if (path_str.startswith("blocks")
                        or re.search(r"(^|/)(enc_k|enc_v|self)(/|$)",
                                     path_str)) else 0
        dims: list = [None] * len(shape)
        bdim = stacked  # batch right after optional layer dim
        if bdim < len(shape):
            dims[bdim] = batch_axes
        if re.search(r"(^|/)(k|v|enc_k|enc_v)$", path_str) and len(shape) >= stacked + 4:
            # (B, T, H, hd): heads on model when they divide; else the TIME
            # dim (flash-decode style sequence-parallel KV).  Sharding
            # head_dim instead puts the contraction dim on the mesh and
            # SPMD all-gathers the full cache in f32 every decode step
            # (measured 1 GiB/layer on internlm2; §Perf iteration 6).
            if shape[stacked + 2] % msize == 0:
                dims[stacked + 2] = AXIS_MODEL
            elif shape[stacked + 1] % msize == 0:
                dims[stacked + 1] = AXIS_MODEL
            else:
                dims[stacked + 3] = AXIS_MODEL
        if re.search(r"/(k|v)_scale$", path_str) and len(shape) >= stacked + 4:
            # int8-KV scales: mirror the k/v plane sharding (minus head_dim)
            if shape[stacked + 2] % msize == 0:
                dims[stacked + 2] = AXIS_MODEL
            elif shape[stacked + 1] % msize == 0:
                dims[stacked + 1] = AXIS_MODEL
        if re.search(r"/ssm$", path_str) and len(shape) >= stacked + 4:
            if shape[stacked + 1] % msize == 0:
                dims[stacked + 1] = AXIS_MODEL  # (B, H, P, N): ssm heads
            else:
                dims[stacked + 3] = AXIS_MODEL  # state dim
        if re.search(r"/conv$", path_str) and len(shape) >= stacked + 3:
            dims[stacked + 2] = AXIS_MODEL      # (B, K-1, C): channels
        if re.search(r"/(ckv|krope)$", path_str) and len(shape) >= stacked + 3:
            # (B, L, r): sequence-parallel latents (same rationale as k/v)
            if shape[stacked + 1] % msize == 0:
                dims[stacked + 1] = AXIS_MODEL
            else:
                dims[stacked + 2] = AXIS_MODEL
        return _guarded_spec(tuple(dims), shape, mesh)

    return jax.tree_util.tree_map_with_path(one, caches)


def make_data_specs(batch_like: Any, mesh: Mesh) -> Any:
    """Token/label/embedding inputs: batch dim on (pod, data)."""
    axes = tuple(a for a in (AXIS_POD, AXIS_DATA) if a in mesh.axis_names)
    baxis = axes if axes else None

    def one(leaf):
        shape = tuple(leaf.shape)
        dims = [None] * len(shape)
        if shape:
            dims[0] = baxis
        return _guarded_spec(tuple(dims), shape, mesh)

    return jax.tree_util.tree_map(one, batch_like)


def make_train_state_specs(state: Any, mesh: Mesh,
                           scfg: ShardingConfig | None = None) -> Any:
    """Specs for {"params", "opt": {"mu", "step"}[, "grad_error"]}.

    fp32 moments mirror their parameter's spec (ZeRO-3: fully sharded with
    the FSDP'd params); int8 QMoment planes shard their flat block axis
    over every mesh axis (pure ZeRO — optimizer state has no TP structure
    to preserve).
    """
    scfg = scfg or ShardingConfig(mode="train")
    pspecs = make_param_specs(state["params"], mesh, scfg)
    all_axes = tuple(a for a in (AXIS_POD, AXIS_DATA, AXIS_MODEL)
                     if a in mesh.axis_names)

    def mu_spec(param_spec, mu):
        def moment(leaf_like):
            # QMoment planes are the param reshaped (*lead, last//b, b):
            # inherit the param's spec with the last axis moved onto the
            # block-count dim (pure within-dim reshape, sharding-exact).
            if hasattr(leaf_like, "_fields"):  # NamedTuple QMoment
                pdims = list(param_spec) if param_spec else []
                pdims += [None] * (len(leaf_like.q.shape) - 1 - len(pdims))
                qdims = tuple(pdims[:-1]) + (pdims[-1] if pdims else None,
                                             None)
                def plane(x):
                    return _guarded_spec(qdims, tuple(x.shape), mesh)
                return type(leaf_like)(
                    plane(leaf_like.q), plane(leaf_like.scale),
                    plane(leaf_like.zero))
            return param_spec
        return {"m": moment(mu["m"]), "v": moment(mu["v"])}

    is_mu = lambda x: isinstance(x, dict) and set(x) == {"m", "v"}
    mu_specs = jax.tree_util.tree_map(
        mu_spec, pspecs, state["opt"]["mu"],
        is_leaf=lambda x: isinstance(x, P) or is_mu(x))
    out = {"params": pspecs,
           "opt": {"mu": mu_specs, "step": P()}}
    if "grad_error" in state:
        out["grad_error"] = pspecs
    return out


def to_named(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# In-graph sharding constraints (steer SPMD where propagation picks badly).
# ---------------------------------------------------------------------------

import contextlib

_ACTIVE_MESH: list = []        # explicit mesh stack (see active_mesh)


@contextlib.contextmanager
def active_mesh(mesh: Mesh):
    """Make ``mesh`` visible to :func:`constrain` during tracing.

    The legacy ``with mesh:`` context does not populate JAX's abstract mesh
    during jit tracing, so in-graph constraints need the mesh threaded
    explicitly.  Launchers (dryrun/train/serve) wrap lowering in this.
    """
    _ACTIVE_MESH.append(mesh)
    try:
        yield mesh
    finally:
        _ACTIVE_MESH.pop()


def _current_axis_sizes():
    if _ACTIVE_MESH:
        m = _ACTIVE_MESH[-1]
        return dict(m.shape), m
    try:
        from jax.sharding import get_abstract_mesh
        m = get_abstract_mesh()
        return dict(zip(m.axis_names, m.axis_sizes)), m
    except Exception:  # noqa: BLE001 — no mesh: constraint is a no-op
        return {}, None


def current_mesh():
    """Public (axis_sizes, mesh) view of the mesh visible at trace time.

    ``mesh`` is the concrete Mesh from :func:`active_mesh` when one is
    installed (required by shard_map-dispatching ops — e.g. the fused
    decode→dequant→matmul paths in ``repro.kernels.ops`` and the
    local-routing MoE), else JAX's abstract mesh, else None; axis_sizes is
    {} when no mesh is visible.
    """
    return _current_axis_sizes()


def constrain(x, *dims):
    """Best-effort ``with_sharding_constraint`` inside jit.

    ``dims`` are mesh-axis names (or tuples of names) per dimension of
    ``x``; axes absent from the active mesh, or that don't divide the dim,
    are dropped — so model code can name ("pod","data")/"model" freely and
    still trace mesh-less (tests, CPU examples) where this is a no-op.
    """
    axis_sizes, mesh = _current_axis_sizes()
    if not axis_sizes:
        return x
    spec = []
    for i, d in enumerate(dims):
        if i >= x.ndim:
            break
        cand = d if isinstance(d, tuple) else (d,) if d else ()
        cand = tuple(a for a in cand if a in axis_sizes)
        total = 1
        for a in cand:
            total *= axis_sizes[a]
        if not cand or x.shape[i] % total != 0:
            spec.append(None)
        else:
            spec.append(cand if len(cand) > 1 else cand[0])
    spec += [None] * (x.ndim - len(spec))
    sharding = P(*spec)
    if isinstance(mesh, Mesh):          # concrete mesh: bind explicitly
        sharding = NamedSharding(mesh, sharding)
    return jax.lax.with_sharding_constraint(x, sharding)


BATCH_AXES = (AXIS_POD, AXIS_DATA)


def constrain_batch(x):
    """Shard dim-0 across (pod, data) — activations along the whole stack."""
    return constrain(x, BATCH_AXES)
