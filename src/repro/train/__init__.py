"""Training runtime: optimizer, steps, data, checkpoint, fault tolerance."""
from .optimizer import AdamWConfig, adamw_init, adamw_update, lr_schedule
from .steps import TrainConfig, make_train_step, init_train_state, cross_entropy
from .data import DataConfig, DataPipeline
from . import checkpoint, fault

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_schedule",
           "TrainConfig", "make_train_step", "init_train_state",
           "cross_entropy", "DataConfig", "DataPipeline", "checkpoint",
           "fault"]
