"""Fault tolerance — checkpoint/restart loop, preemption, elastic remesh,
straggler mitigation.

The container is single-process, but the control flow here is exactly what
a 1000-node launcher wraps around its per-host main():

  * ``FaultTolerantLoop`` — periodic + on-signal checkpoints, automatic
    resume from the latest committed step, bounded retry on transient step
    failures (the multi-host analogue: a failed collective raises on every
    healthy host; all hosts re-enter from the same committed step).
  * ``elastic_restore`` — the same checkpoint restores onto a *different*
    mesh (fewer/more hosts after failure/scale-up): leaves are resharded
    by device_put with the new mesh's NamedSharding; the step-indexed data
    pipeline keeps the sample order aligned.
  * Straggler mitigation (design note, exercised in tests via the timeout
    hook): training is synchronous-SPMD, so a straggling host slows the
    all-reduce for everyone.  The loop exposes ``step_timeout_s``; on
    expiry the launcher's action is to evict the slow host and elastic-
    restart on the survivors — which is exactly ``elastic_restore``.
    Within-step mitigation (backup experts / skip-straggler collectives)
    is deliberately NOT done: it changes numerics.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Optional

import jax

from . import checkpoint as ckpt


@dataclasses.dataclass
class FaultConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    max_step_retries: int = 2
    step_timeout_s: float = 0.0      # 0 = disabled
    handle_sigterm: bool = True      # preemption checkpoint


class PreemptionGuard:
    """Flags SIGTERM/SIGINT so the loop checkpoints before exiting —
    the on-prem analogue of a TPU maintenance-event hook.

    Both signals are registered (SIGTERM = scheduler preemption, SIGINT =
    operator ^C); ``restore()`` reinstates the previous handlers so guards
    can be scoped (tests, nested launchers)."""

    SIGNALS = (signal.SIGTERM, signal.SIGINT)

    def __init__(self, enable: bool = True):
        self.fired = False
        self._prev = {}
        if enable:
            for sig in self.SIGNALS:
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:
                    pass  # non-main thread (tests)

    def _handler(self, signum, frame):
        self.fired = True

    def restore(self):
        """Reinstate the handlers that were active before this guard."""
        for sig, prev in self._prev.items():
            try:
                signal.signal(sig, prev)
            except ValueError:
                pass
        self._prev = {}


class FaultTolerantLoop:
    def __init__(self, train_step: Callable, state: Any, data, fcfg: FaultConfig,
                 *, state_shardings: Any = None,
                 on_metrics: Optional[Callable] = None):
        self.train_step = train_step
        self.state = state
        self.data = data
        self.fcfg = fcfg
        self.state_shardings = state_shardings
        self.on_metrics = on_metrics
        self.guard = PreemptionGuard(fcfg.handle_sigterm)
        self.start_step = 0

    def maybe_resume(self) -> int:
        """Restore the newest *loadable* committed checkpoint if one exists.

        A corrupt/unreadable newest step (flash bit rot, torn shard) falls
        back to the previous COMMIT-marked step instead of raising — the
        restart must come up on whatever good state survives."""
        try:
            self.state, last = ckpt.restore_latest(
                self.fcfg.ckpt_dir, self.state,
                shardings=self.state_shardings)
            self.start_step = last
        except FileNotFoundError:
            pass  # no checkpoint (or none loadable): cold start from 0
        return self.start_step

    def _checkpoint(self, step: int):
        ckpt.save(self.fcfg.ckpt_dir, step, self.state)
        ckpt.prune_old(self.fcfg.ckpt_dir, self.fcfg.keep)

    def run(self, num_steps: int) -> Any:
        step = self.start_step
        while step < num_steps:
            batch = self.data.batch_at(step)
            t0 = time.monotonic()
            for attempt in range(self.fcfg.max_step_retries + 1):
                try:
                    self.state, metrics = self.train_step(self.state, batch)
                    # Block so failures surface inside the retry scope.
                    jax.block_until_ready(metrics["loss"])
                    break
                except jax.errors.JaxRuntimeError:
                    if attempt == self.fcfg.max_step_retries:
                        # Persistent failure: checkpoint what we have and
                        # re-raise for the launcher to elastic-restart.
                        self._checkpoint(step)
                        raise
            dt = time.monotonic() - t0
            if self.fcfg.step_timeout_s and dt > self.fcfg.step_timeout_s:
                # Straggler signal: surface to the launcher via metrics.
                metrics = {**metrics, "straggler": True, "step_time_s": dt}
            step += 1
            if self.on_metrics:
                self.on_metrics(step, metrics)
            if step % self.fcfg.ckpt_every == 0 or self.guard.fired:
                self._checkpoint(step)
                if self.guard.fired:
                    break
        # final checkpoint so restarts are seamless
        self._checkpoint(step)
        return self.state


def elastic_restore(ckpt_dir: str, like_state: Any, new_mesh,
                    make_shardings: Callable[[Any, Any], Any]):
    """Restore the latest checkpoint onto a different mesh.

    ``make_shardings(state, mesh) -> tree of NamedSharding`` lets the
    caller rebuild partition specs for the survivor topology.
    """
    last = ckpt.latest_step(ckpt_dir)
    if last is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    shardings = make_shardings(like_state, new_mesh)
    state = ckpt.restore(ckpt_dir, last, like_state, shardings=shardings)
    return state, last
