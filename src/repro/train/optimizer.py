"""Optimizers — AdamW in pure JAX, with an int8-quantized-state variant.

The int8 variant applies the paper's own quantizer to the Adam moments
(per-block affine int8, block=256), cutting optimizer HBM from 8 to ~2.06
bytes/param — the Tiny-QMoE idea pointed at training state instead of
inference weights (beyond-paper; DESIGN.md §5).  Error stays bounded
because moments are re-quantized from fresh fp32 values each step
(quantize-after-update, as in 8-bit Adam).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantized_state: bool = False    # int8 moments (beyond-paper)
    qblock: int = 256
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class QMoment(NamedTuple):
    """int8 moment payload + per-block affine params.

    Blocks run along the param's LAST dim only: ``q`` is shaped
    (*param.shape[:-1], last//block, block) — a pure within-dim reshape, so
    every plane inherits the param's sharding (FSDP/TP) untouched.  A flat
    whole-tensor blocking would need a global reshape across shard
    boundaries, which XLA materializes as a full all-gather of the moments
    (measured 204 GiB/dev on llama3-405b; §Perf iteration 4).
    """
    q: jax.Array        # uint8 codes, (*lead, nb, block)
    scale: jax.Array    # f32 (*lead, nb, 1)
    zero: jax.Array     # f32 (*lead, nb, 1)


def moment_block(last_dim: int, block: int) -> int:
    """Largest block ≤ ``block`` dividing ``last_dim`` (power-of-2 search)."""
    b = min(block, last_dim)
    while last_dim % b:
        b //= 2
    return max(b, 1)


def quantizable(p, cfg: AdamWConfig) -> bool:
    return (cfg.quantized_state and p.ndim >= 2
            and p.shape[-1] >= 8 and p.size >= cfg.qblock)


def _q_moment(x: jax.Array, block: int) -> QMoment:
    *lead, last = x.shape
    b = moment_block(last, block)
    rows = x.reshape(*lead, last // b, b).astype(jnp.float32)
    mn = rows.min(axis=-1, keepdims=True)
    mx = rows.max(axis=-1, keepdims=True)
    scale = jnp.maximum((mx - mn) / 255.0, 1e-12)
    q = jnp.clip(jnp.round((rows - mn) / scale), 0, 255).astype(jnp.uint8)
    return QMoment(q, scale, mn)


def _dq_moment(qm: QMoment, shape, dtype=jnp.float32) -> jax.Array:
    rows = qm.q.astype(jnp.float32) * qm.scale + qm.zero
    return rows.reshape(shape).astype(dtype)


def adamw_init(params: Any, cfg: AdamWConfig) -> Any:
    def one(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if quantizable(p, cfg):
            return {"m": _q_moment(z, cfg.qblock),
                    "v": _q_moment(z, cfg.qblock)}
        return {"m": z, "v": z}
    return {"mu": jax.tree_util.tree_map(one, params),
            "step": jnp.zeros((), jnp.int32)}


def lr_schedule(step, cfg: AdamWConfig):
    """Linear warmup → cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(params: Any, grads: Any, state: Any, cfg: AdamWConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = lr_schedule(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def one_inner(p, g, mu, decay: bool):
        gf = g.astype(jnp.float32) * clip
        quantized = isinstance(mu["m"], QMoment)
        m_prev = (_dq_moment(mu["m"], p.shape) if quantized
                  else mu["m"])
        v_prev = (_dq_moment(mu["v"], p.shape) if quantized
                  else mu["v"])
        m = cfg.b1 * m_prev + (1 - cfg.b1) * gf
        v = cfg.b2 * v_prev + (1 - cfg.b2) * gf * gf
        mh = m / b1c
        vh = v / b2c
        upd = mh / (jnp.sqrt(vh) + cfg.eps)
        if decay:  # decoupled weight decay on matrices only
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        if quantized:
            new_mu = {"m": _q_moment(m, cfg.qblock),
                      "v": _q_moment(v, cfg.qblock)}
        else:
            new_mu = {"m": m, "v": v}
        return newp, new_mu

    def one(p, g, mu):
        # NOTE(§Perf iteration 5, refuted): updating layer-stacked leaves
        # one layer at a time via lax.map shrinks the f32 moment temps L×,
        # but breaks XLA's input→output buffer aliasing across the scan, so
        # params+moments live twice (+18 GiB/dev on kimi-k2 — net LOSS).
        # Direct per-leaf update keeps donation-based aliasing.
        return one_inner(p, g, mu, p.ndim >= 2)

    is_mu = lambda x: isinstance(x, dict) and set(x) == {"m", "v"}
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = jax.tree_util.tree_flatten(state["mu"], is_leaf=is_mu)[0]

    if cfg.quantized_state:
        # Serialize per-tensor updates (barrier-chained token): the int8
        # moment (de)quantize needs several f32 temps of the tensor, and
        # XLA otherwise schedules many tensors' updates concurrently —
        # ~8 live 5 GiB temps on kimi-k2 (§Perf K1).  The optimizer is
        # bandwidth-bound; sequencing costs no step time.
        out = []
        token = jnp.zeros((), jnp.float32)
        for p, g, mu in zip(flat_p, flat_g, flat_mu):
            g = g + token.astype(g.dtype)          # schedule dependency
            newp, new_mu = one(p, g, mu)
            leaves = jax.tree_util.tree_leaves((newp, new_mu))
            barried = jax.lax.optimization_barrier(tuple(leaves) + (token,))
            token = barried[-1]
            rebuilt = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure((newp, new_mu)), barried[:-1])
            out.append(rebuilt)
    else:
        out = [one(p, g, mu) for p, g, mu in zip(flat_p, flat_g, flat_mu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = jax.tree_util.tree_flatten(state["mu"], is_leaf=is_mu)[1] \
        .unflatten([o[1] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "step": step}, metrics
