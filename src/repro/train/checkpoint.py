"""Sharded checkpointing with atomic commit and elastic restore.

Layout (one directory per step):
    ckpt_dir/step_000420/
      manifest.json        # treedef paths, shapes, dtypes, step, mesh shape
      shard_<host>.npz     # this host's param/opt leaves (addressable data)
      COMMIT               # written last — presence marks validity

Design points for 1000+-node runs (single-process container exercises the
same code paths):
  * atomic commit marker → a preempted writer never corrupts the latest
    valid checkpoint; ``latest_step`` skips uncommitted dirs.
  * per-host shard files → writes scale with hosts, no gather to host 0.
  * restore-with-reshard: leaves are loaded whole then ``device_put`` with
    the *target* mesh's NamedSharding — restoring a (16,16) checkpoint
    onto (8,16) or (2,16,16) "elastic" meshes is the same call.
  * step-indexed data pipeline (data.py) makes restarts bit-deterministic.
  * integrity: the manifest records a per-leaf CRC32 alongside shapes and
    dtypes; ``restore`` validates all three against both the manifest and
    the target structure *before* unflattening (a flash-rotted or torn
    shard raises ``CheckpointCorruptError`` naming the leaf instead of
    silently loading garbage), and ``restore_latest`` walks back to the
    previous COMMIT-marked step when the newest one is unreadable or
    corrupt.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import zlib
from typing import Any

import jax
import numpy as np

COMMIT = "COMMIT"


class CheckpointCorruptError(ValueError):
    """A committed checkpoint failed shape/dtype/checksum validation."""


def _leaf_crc(arr: np.ndarray) -> int:
    a = np.ascontiguousarray(arr)
    raw = a.reshape(-1).view(np.uint8) if a.size else np.zeros(0, np.uint8)
    return zlib.crc32(raw) & 0xFFFFFFFF


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, host_id: int = 0,
         extra: dict | None = None) -> str:
    """Write one checkpoint atomically; returns the step directory."""
    names, leaves, _ = _flatten_with_names(tree)
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir if os.path.isdir(ckpt_dir) else None,
                           prefix=f".tmp_step_{step:08d}_")
    try:
        arrs = {}
        for name, leaf in zip(names, leaves):
            arrs[name] = np.asarray(jax.device_get(leaf))
        np.savez(os.path.join(tmp, f"shard_{host_id:05d}.npz"), **arrs)
        manifest = {
            "step": step,
            "names": names,
            "shapes": [list(a.shape) for a in arrs.values()],
            "dtypes": [str(a.dtype) for a in arrs.values()],
            "crc32": [_leaf_crc(a) for a in arrs.values()],
            "hosts": 1,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, COMMIT), "w") as f:
            f.write("ok")
        os.makedirs(ckpt_dir, exist_ok=True)
        if os.path.isdir(step_dir):
            shutil.rmtree(step_dir)
        os.replace(tmp, step_dir)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return step_dir


def latest_step(ckpt_dir: str) -> int | None:
    """Newest *committed* step, skipping torn writes."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, COMMIT)):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def _load_manifest(step_dir: str) -> dict:
    try:
        with open(os.path.join(step_dir, "manifest.json")) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(f"unreadable manifest in {step_dir}: "
                                     f"{e}") from e


def _validate_manifest(manifest: dict, names, leaves, step_dir: str):
    """Per-leaf shape/dtype validation against the *target* structure —
    never trust the manifest (or the shards) blindly before unflattening."""
    m_names = manifest.get("names", [])
    m_shapes = {n: tuple(s) for n, s in zip(m_names,
                                            manifest.get("shapes", []))}
    for name, leaf in zip(names, leaves):
        if name not in m_shapes:
            raise CheckpointCorruptError(
                f"{step_dir}: manifest missing leaf {name}")
        if m_shapes[name] != tuple(leaf.shape):
            raise CheckpointCorruptError(
                f"{name}: ckpt {m_shapes[name]} vs model "
                f"{tuple(leaf.shape)}")


def restore(ckpt_dir: str, step: int, like: Any, *,
            shardings: Any = None, verify: bool = True) -> Any:
    """Load a checkpoint into the structure of ``like``.

    ``shardings``: optional matching tree of NamedSharding for the *target*
    mesh (elastic restore); plain device_put otherwise.  ``verify=True``
    checks every leaf's shape/dtype against the manifest and target
    structure and, for checkpoints that carry them, the per-leaf CRC32
    checksums — raising ``CheckpointCorruptError`` (naming the leaf)
    *before* any state is unflattened.
    """
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.exists(os.path.join(step_dir, COMMIT)):
        raise FileNotFoundError(f"no committed checkpoint at {step_dir}")
    names, leaves, treedef = _flatten_with_names(like)
    manifest = _load_manifest(step_dir)
    if verify:
        _validate_manifest(manifest, names, leaves, step_dir)
    crcs = dict(zip(manifest.get("names", []), manifest.get("crc32", [])))
    m_dtypes = dict(zip(manifest.get("names", []),
                        manifest.get("dtypes", [])))
    data = {}
    try:
        for fn in sorted(os.listdir(step_dir)):
            if fn.startswith("shard_") and fn.endswith(".npz"):
                with np.load(os.path.join(step_dir, fn)) as z:
                    for k in z.files:
                        data[k] = z[k]
    except Exception as e:   # truncated/garbled archive (zipfile/np errors)
        raise CheckpointCorruptError(
            f"unreadable shard in {step_dir}: {e}") from e
    out = []
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
        if shardings is not None else [None] * len(names))
    # validate every leaf first; only then device_put/unflatten
    arrs = []
    for name, leaf in zip(names, leaves):
        if name not in data:
            raise CheckpointCorruptError(f"checkpoint missing leaf {name}")
        arr = data[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise CheckpointCorruptError(
                f"{name}: ckpt {arr.shape} vs model {tuple(leaf.shape)}")
        if verify and m_dtypes.get(name, str(arr.dtype)) != str(arr.dtype):
            raise CheckpointCorruptError(
                f"{name}: shard dtype {arr.dtype} vs manifest "
                f"{m_dtypes[name]}")
        if verify and name in crcs and _leaf_crc(arr) != crcs[name]:
            raise CheckpointCorruptError(
                f"{name}: checksum mismatch (bit rot or torn shard)")
        arrs.append(arr.astype(leaf.dtype))
    for arr, shd in zip(arrs, shard_leaves):
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.device_put(arr))
    return treedef.unflatten(out)


def restore_latest(ckpt_dir: str, like: Any, *, shardings: Any = None,
                   on_skip=None):
    """Restore the newest *loadable* committed checkpoint.

    Walks committed steps newest → oldest; a step that fails validation
    (unreadable shard, checksum/shape mismatch) is skipped — flash bit rot
    on the newest step must not strand a host that still has an older
    good one — and ``on_skip(step, exc)`` is notified.  Returns
    ``(state, step)``; raises FileNotFoundError when no step loads.
    """
    if not os.path.isdir(ckpt_dir):
        raise FileNotFoundError(f"no checkpoint dir {ckpt_dir}")
    steps = sorted(
        (int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
         if d.startswith("step_") and
         os.path.exists(os.path.join(ckpt_dir, d, COMMIT))),
        reverse=True)
    last_exc = None
    for s in steps:
        try:
            return restore(ckpt_dir, s, like, shardings=shardings), s
        except (CheckpointCorruptError, KeyError, OSError) as e:
            last_exc = e
            if on_skip is not None:
                on_skip(s, e)
    raise FileNotFoundError(
        f"no loadable committed checkpoint under {ckpt_dir}"
        + (f" (last error: {last_exc})" if last_exc else ""))


def prune_old(ckpt_dir: str, keep: int = 3) -> None:
    """Keep the newest ``keep`` committed checkpoints (GC for long runs)."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and
        os.path.exists(os.path.join(ckpt_dir, d, COMMIT)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
