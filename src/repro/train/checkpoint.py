"""Sharded checkpointing with atomic commit and elastic restore.

Layout (one directory per step):
    ckpt_dir/step_000420/
      manifest.json        # treedef paths, shapes, dtypes, step, mesh shape
      shard_<host>.npz     # this host's param/opt leaves (addressable data)
      COMMIT               # written last — presence marks validity

Design points for 1000+-node runs (single-process container exercises the
same code paths):
  * atomic commit marker → a preempted writer never corrupts the latest
    valid checkpoint; ``latest_step`` skips uncommitted dirs.
  * per-host shard files → writes scale with hosts, no gather to host 0.
  * restore-with-reshard: leaves are loaded whole then ``device_put`` with
    the *target* mesh's NamedSharding — restoring a (16,16) checkpoint
    onto (8,16) or (2,16,16) "elastic" meshes is the same call.
  * step-indexed data pipeline (data.py) makes restarts bit-deterministic.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np

COMMIT = "COMMIT"


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, host_id: int = 0,
         extra: dict | None = None) -> str:
    """Write one checkpoint atomically; returns the step directory."""
    names, leaves, _ = _flatten_with_names(tree)
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir if os.path.isdir(ckpt_dir) else None,
                           prefix=f".tmp_step_{step:08d}_")
    try:
        arrs = {}
        for name, leaf in zip(names, leaves):
            arrs[name] = np.asarray(jax.device_get(leaf))
        np.savez(os.path.join(tmp, f"shard_{host_id:05d}.npz"), **arrs)
        manifest = {
            "step": step,
            "names": names,
            "shapes": [list(a.shape) for a in arrs.values()],
            "dtypes": [str(a.dtype) for a in arrs.values()],
            "hosts": 1,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, COMMIT), "w") as f:
            f.write("ok")
        os.makedirs(ckpt_dir, exist_ok=True)
        if os.path.isdir(step_dir):
            shutil.rmtree(step_dir)
        os.replace(tmp, step_dir)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return step_dir


def latest_step(ckpt_dir: str) -> int | None:
    """Newest *committed* step, skipping torn writes."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, COMMIT)):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, *,
            shardings: Any = None) -> Any:
    """Load a checkpoint into the structure of ``like``.

    ``shardings``: optional matching tree of NamedSharding for the *target*
    mesh (elastic restore); plain device_put otherwise.
    """
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.exists(os.path.join(step_dir, COMMIT)):
        raise FileNotFoundError(f"no committed checkpoint at {step_dir}")
    names, leaves, treedef = _flatten_with_names(like)
    data = {}
    for fn in sorted(os.listdir(step_dir)):
        if fn.startswith("shard_") and fn.endswith(".npz"):
            with np.load(os.path.join(step_dir, fn)) as z:
                for k in z.files:
                    data[k] = z[k]
    out = []
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
        if shardings is not None else [None] * len(names))
    for name, leaf, shd in zip(names, leaves, shard_leaves):
        if name not in data:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = data[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{name}: ckpt {arr.shape} vs model {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.device_put(arr))
    return treedef.unflatten(out)


def prune_old(ckpt_dir: str, keep: int = 3) -> None:
    """Keep the newest ``keep`` committed checkpoints (GC for long runs)."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and
        os.path.exists(os.path.join(ckpt_dir, d, COMMIT)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
