"""Training step functions — loss, grads, optimizer, gradient compression.

``make_train_step`` builds the jit-able step for any arch family; the
returned function's (in_shardings, out_shardings) come from
``repro.sharding``.  Gradient compression (int8 + error feedback, the
paper's quantizer on the wire) is a config flag.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import lm as LM
from repro.models import encdec as ED
from repro.sharding.partition import constrain
from .optimizer import AdamWConfig, adamw_update, adamw_init


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    z_loss: float = 1e-4
    moe_aux_weight: float = 1e-2
    grad_compression: str = "none"      # none | int8_ef
    param_dtype: Any = jnp.float32
    logits_chunk: int = 0               # 0 = no chunking
    accum_steps: int = 1                # gradient-accumulation microbatches
    # accumulator dtype: f32 default; bf16 halves the dominant train-state
    # buffer for 1T-scale models (§Perf K2) at ~1e-3 relative grad error
    accum_dtype: Any = jnp.float32


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 0.0):
    """Token-mean CE with optional z-loss; logits (B,T,V), labels (B,T)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - ll)
    if z_loss:
        ce = ce + z_loss * jnp.mean(lse ** 2)
    return ce


def chunked_cross_entropy(hidden: jax.Array, head: jax.Array,
                          labels: jax.Array, *, chunk: int,
                          z_loss: float = 0.0,
                          softcap: float = 0.0) -> jax.Array:
    """CE without materializing (B, T, V) logits.

    Scans sequence chunks: each step computes a (B, c, V) logits slice,
    reduces it to scalars, and ``jax.checkpoint`` forces the slice to be
    recomputed in the backward pass instead of saved.  Peak logits memory
    drops by T/chunk (e.g. 4096/512 = 8×) — the lever that lets the
    256k-vocab archs (seamless 256206, llama3-405b 128256) fit the train
    shape (EXPERIMENTS.md §Perf).

    hidden: (B, T, d); head: (V, d); labels: (B, T).
    """
    b, t, d = hidden.shape
    c = min(chunk, t)
    while t % c:
        c -= 1
    n = t // c
    hs = hidden.reshape(b, n, c, d).swapaxes(0, 1)     # (n, B, c, d)
    ls = labels.reshape(b, n, c).swapaxes(0, 1)        # (n, B, c)

    @jax.checkpoint
    def body(acc, xs):
        h, lab = xs
        logits = jnp.einsum("bcd,vd->bcv", h.astype(jnp.float32),
                            head.astype(jnp.float32))
        # Keep the logits slice sharded (batch × vocab-TP): SPMD propagation
        # otherwise replicates it when hidden's batch and head's d_model both
        # live on the data axis (measured 31 GiB/dev → 131 MiB/dev, §Perf).
        logits = constrain(logits, ("pod", "data"), None, "model")
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        ce_sum, z_sum = acc
        return (ce_sum + jnp.sum(lse - ll), z_sum + jnp.sum(lse ** 2)), None

    (ce_sum, z_sum), _ = jax.lax.scan(body, (jnp.float32(0.0),
                                             jnp.float32(0.0)), (hs, ls))
    ce = ce_sum / (b * t)
    if z_loss:
        ce = ce + z_loss * z_sum / (b * t)
    return ce


def _loss_fn(params, cfg, tcfg: TrainConfig, batch, lut=None):
    fam = cfg.family
    chunked = tcfg.logits_chunk > 0
    if fam == "encdec":
        out, _ = ED.forward(params, cfg, batch["enc_embeds"],
                            batch["tokens"], lut=lut, return_hidden=chunked)
        aux = 0.0
    else:
        out, _, aux = LM.forward(params, cfg, batch["tokens"],
                                 embeds=batch.get("embeds"), lut=lut,
                                 return_hidden=chunked)
        if fam == "vlm" and batch.get("embeds") is not None:
            out = out[:, batch["embeds"].shape[1]:]
    if chunked:
        head = params.get("lm_head", params.get("embed"))
        loss = chunked_cross_entropy(out, head, batch["labels"],
                                     chunk=tcfg.logits_chunk,
                                     z_loss=tcfg.z_loss,
                                     softcap=cfg.logits_softcap)
    else:
        loss = cross_entropy(out, batch["labels"], tcfg.z_loss)
    if cfg.is_moe:
        loss = loss + tcfg.moe_aux_weight * aux
    return loss, {"ce": loss}


def compress_grads_int8(grads, error_fb):
    """int8 gradient compression with error feedback (per-tensor affine).

    Models the wire format of a compressed cross-pod all-reduce: quantize
    (g + e) to int8, dequantize for the update, keep the residual as the
    next step's feedback.  Under pjit the all-reduce itself is inserted by
    XLA; this shapes *what* is reduced.
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        mn = jnp.min(gf)
        mx = jnp.max(gf)
        scale = jnp.maximum((mx - mn) / 255.0, 1e-12)
        q = jnp.clip(jnp.round((gf - mn) / scale), 0, 255)
        dq = q * scale + mn
        return dq.astype(g.dtype), gf - dq

    flat_g, td = jax.tree_util.tree_flatten(grads)
    flat_e = td.flatten_up_to(error_fb)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])


def make_train_step(cfg, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", ["grad_error"]}.
    """
    use_ef = tcfg.grad_compression == "int8_ef"

    def _grads(params, batch):
        """Loss + grads, with optional microbatched accumulation: the batch
        splits on its leading dim and a lax.scan accumulates grads — the
        standard activation-memory lever for the giant train shapes (one
        microbatch's activations live at a time)."""
        if tcfg.accum_steps <= 1:
            return jax.value_and_grad(_loss_fn, has_aux=True)(
                params, cfg, tcfg, batch)

        a = tcfg.accum_steps

        def split(x):
            b = x.shape[0]
            assert b % a == 0, (b, a)
            return x.reshape((a, b // a) + x.shape[1:])

        micro = jax.tree_util.tree_map(split, batch)

        def body(acc, mb):
            (l, p), g = jax.value_and_grad(_loss_fn, has_aux=True)(
                params, cfg, tcfg, mb)
            acc_g, acc_l = acc
            acc_g = jax.tree_util.tree_map(
                lambda x, y: x + y.astype(x.dtype), acc_g, g)
            return (acc_g, acc_l + l), None

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, tcfg.accum_dtype), params)
        (gsum, lsum), _ = jax.lax.scan(body, (g0, jnp.float32(0.0)), micro)
        # keep the accumulator dtype here — the optimizer casts per tensor,
        # so a global f32 view (2× param bytes) never materializes
        gavg = jax.tree_util.tree_map(lambda x: x / a, gsum)
        loss = lsum / a
        return (loss, {"ce": loss}), gavg

    def train_step(state, batch):
        params = state["params"]
        (loss, parts), grads = _grads(params, batch)
        if use_ef:
            grads, new_err = compress_grads_int8(grads, state["grad_error"])
        new_params, new_opt, om = adamw_update(params, grads, state["opt"],
                                               tcfg.optimizer)
        new_state = {"params": new_params, "opt": new_opt}
        if use_ef:
            new_state["grad_error"] = new_err
        metrics = {"loss": loss, **om}
        return new_state, metrics

    return train_step


def init_train_state(params, tcfg: TrainConfig):
    state = {"params": params, "opt": adamw_init(params, tcfg.optimizer)}
    if tcfg.grad_compression == "int8_ef":
        state["grad_error"] = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state
