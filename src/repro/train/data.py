"""Data pipeline — deterministic, step-indexed, restart-safe.

Every batch is a pure function of (seed, step), so a restarted job resumes
mid-epoch with zero bookkeeping (the checkpoint stores only the step).
Two sources:
  * synthetic markov streams — self-correlated token data whose next-token
    structure a model can actually learn (loss goes down); used by the
    e2e training example and accuracy benchmarks.
  * byte corpus — any local file served as uint8 tokens (vocab 256), used
    by the paper-fidelity perplexity benchmark.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    kind: str = "markov"            # markov | bytes
    corpus_path: str | None = None
    order_mix: float = 0.7          # markov: P(follow chain) vs uniform


def _markov_table(vocab: int, seed: int) -> np.ndarray:
    """Sparse-ish row-stochastic transition table (deterministic)."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, size=(vocab, 4))
    return succ


class DataPipeline:
    """Host-side generator; ``batch_at(step)`` is random-access."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.kind == "markov":
            self._succ = _markov_table(cfg.vocab_size, cfg.seed)
        elif cfg.kind == "bytes":
            with open(cfg.corpus_path, "rb") as f:
                self._bytes = np.frombuffer(f.read(), dtype=np.uint8)
            assert len(self._bytes) > cfg.seq_len + 1
        else:
            raise ValueError(cfg.kind)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        if cfg.kind == "bytes":
            starts = rng.integers(0, len(self._bytes) - cfg.seq_len - 1,
                                  size=cfg.batch)
            toks = np.stack([self._bytes[s:s + cfg.seq_len + 1]
                             for s in starts]).astype(np.int32)
        else:
            toks = np.empty((cfg.batch, cfg.seq_len + 1), np.int32)
            cur = rng.integers(0, cfg.vocab_size, size=cfg.batch)
            toks[:, 0] = cur
            for t in range(1, cfg.seq_len + 1):
                follow = rng.random(cfg.batch) < cfg.order_mix
                pick = rng.integers(0, 4, size=cfg.batch)
                nxt_chain = self._succ[cur, pick]
                nxt_rand = rng.integers(0, cfg.vocab_size, size=cfg.batch)
                cur = np.where(follow, nxt_chain, nxt_rand)
                toks[:, t] = cur
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
