"""Launchers: mesh construction, dry-run, train/serve drivers."""
from .mesh import make_production_mesh, make_mesh, make_host_mesh

__all__ = ["make_production_mesh", "make_mesh", "make_host_mesh"]
