"""Post-optimization HLO analysis — collective bytes, while-loop awareness.

``collective_stats(compiled.as_text())`` walks every computation, sums the
operand/result bytes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops, and multiplies ops living inside
while-loop bodies by the loop trip count (best-effort parse of the loop
condition's comparison constant — exact for lax.scan loops, which is the
only loop source in this codebase).

Link-traffic conversion (ring algorithms, n = shard count) happens in
``benchmarks/roofline.py``; this module reports raw byte sums per op kind.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast",
                "ragged-all-to-all")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    ops: dict                  # kind -> count (trip-weighted)
    bytes_by_kind: dict        # kind -> result bytes (trip-weighted)
    operand_bytes_by_kind: dict
    total_bytes: int
    while_trips: dict          # body name -> trip count

    def as_dict(self):
        return {
            "ops": dict(self.ops),
            "bytes_by_kind": dict(self.bytes_by_kind),
            "operand_bytes_by_kind": dict(self.operand_bytes_by_kind),
            "total_bytes": int(self.total_bytes),
            "while_trips": dict(self.while_trips),
        }


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """computation name -> lines."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        # header: `%name (args...) -> type {` — args may contain nested
        # tuple parens, so only anchor on the name + trailing `-> ... {`
        m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(", stripped)
        if m and stripped.endswith("{") and "->" in stripped:
            cur = m.group(2)
            comps[cur] = []
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _find_while_trips(comps: dict[str, list[str]]) -> dict[str, int]:
    """body computation name -> trip count (via condition's compare const).

    lax.scan conditions compile to ``compare(iv, constant(N)), direction=LT``
    (or constant first).  We take the largest integer constant in the
    condition computation — exact for scan, conservative otherwise.
    """
    # map condition/body names per while op
    body_cond: list[tuple[str, str]] = []
    for lines in comps.values():
        for ln in lines:
            if " while(" in ln:
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                if mb and mc:
                    body_cond.append((mb.group(1), mc.group(1)))
    trips: dict[str, int] = {}
    for body, cond in body_cond:
        best = 1
        for ln in comps.get(cond, []):
            for m in re.finditer(r"constant\((\d+)\)", ln):
                best = max(best, int(m.group(1)))
            # constants may be hoisted as s32[] constants on their own line
            m2 = re.search(r"=\s*[su]\d+\[\]\s*constant\((\d+)\)", ln)
            if m2:
                best = max(best, int(m2.group(1)))
        trips[body] = best
    return trips


def collective_stats(hlo_text: str) -> CollectiveStats:
    comps = _split_computations(hlo_text)
    trips = _find_while_trips(comps)

    ops: dict = defaultdict(int)
    rbytes: dict = defaultdict(int)
    obytes: dict = defaultdict(int)

    for cname, lines in comps.items():
        weight = trips.get(cname, 1)
        for ln in lines:
            for kind in _COLLECTIVES:
                # match " kind(" or " kind-start(" as the op name after '='
                m = re.search(
                    rf"=\s*(.+?)\s{re.escape(kind)}(-start)?\(", ln)
                if not m:
                    continue
                result_type = m.group(1)
                # operand types appear inside the call parens
                call = ln[m.end():]
                rb = _type_bytes(result_type)
                ob = _type_bytes(call.split("), ")[0] + ")")
                ops[kind] += weight
                rbytes[kind] += rb * weight
                obytes[kind] += ob * weight
                break

    total = sum(rbytes.values())
    return CollectiveStats(ops=ops, bytes_by_kind=rbytes,
                           operand_bytes_by_kind=obytes,
                           total_bytes=total, while_trips=trips)


# ---------------------------------------------------------------------------
# Trip-weighted FLOP/byte model.
#
# ``compiled.cost_analysis()`` counts every while body ONCE — a scanned
# 126-layer model with 64 accumulation microbatches is undercounted ~8000×.
# This walks the optimized HLO with a computation-weight map (ENTRY=1, while
# bodies multiply by their trip count, nested scans multiply through),
# counts dot FLOPs from operand/result shapes, and models memory traffic as
# (operands + result) bytes of every top-level op (fusion internals are
# counted at their call site — XLA reads fusion operands once and writes
# one result, so this matches the fusion's actual HBM traffic).
# ---------------------------------------------------------------------------

_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\S+(?:\[[0-9,]*\])?\S*)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "partition-id", "replica-id",
    "opt-barrier", "optimization-barrier", "iota", "custom-call",
}


def _parse_defs(lines):
    """name -> (type_str, op, line) for one computation."""
    defs = {}
    for ln in lines:
        m = _DEF_RE.match(ln)
        if m:
            defs[m.group(1)] = (m.group(2), m.group(3), ln)
    return defs


def _dims(type_str):
    m = re.match(r"[a-z0-9]+\[([0-9,]*)\]", type_str)
    if not m:
        return None
    return [int(d) for d in m.group(1).split(",") if d]


def _dot_flops(ln, defs) -> int:
    """2 · prod(result) · prod(contracting dims of lhs)."""
    m = _DEF_RE.match(ln)
    if not m:
        return 0
    result_dims = _dims(m.group(2))
    if result_dims is None:
        return 0
    args = ln[ln.index("("):]
    ops = _OPERAND_RE.findall(args.split(")")[0])
    if not ops or ops[0] not in defs:
        return 0
    lhs_dims = _dims(defs[ops[0]][0])
    if lhs_dims is None:
        return 0
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln)
    contract = 1
    if mc:
        for d in mc.group(1).split(","):
            if d:
                contract *= lhs_dims[int(d)]
    n = 1
    for d in result_dims:
        n *= d
    return 2 * n * contract


def _computation_weights(comps, trips) -> dict:
    """ENTRY-reachable weights; while bodies/conds multiply by trip count,
    composing through nesting.  Fusion/reducer computations get weight 0
    (their cost is accounted at the call site)."""
    # map: computation -> list of (callee, kind) edges
    body_cond: dict[str, tuple[str, str]] = {}
    for cname, lines in comps.items():
        for ln in lines:
            if " while(" in ln:
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                if mb and mc:
                    body_cond.setdefault(cname, None)
    weights = {c: 0.0 for c in comps}
    entry = None
    for c in comps:
        if c.startswith("main") or entry is None:
            entry = c if c.startswith("main") else entry
    # ENTRY computation: the one never referenced as body/cond/calls target
    referenced = set()
    for lines in comps.values():
        for ln in lines:
            for m in re.finditer(r"(?:body|condition|calls|to_apply)=%?([\w\.\-]+)", ln):
                referenced.add(m.group(1))
    roots = [c for c in comps if c not in referenced]
    stack = [(r, 1.0) for r in roots]
    while stack:
        cname, w = stack.pop()
        if w <= weights.get(cname, 0.0) and weights.get(cname, 0.0) > 0:
            continue
        weights[cname] = max(weights.get(cname, 0.0), w)
        for ln in comps.get(cname, ()):
            if " while(" in ln:
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                if mb:
                    t = trips.get(mb.group(1), 1)
                    stack.append((mb.group(1), w * t))
                    if mc:
                        stack.append((mc.group(1), w * t))
    return weights


_SLICE_OPS = {"dynamic-slice", "slice"}


def _fusion_read_bytes(fusion_ln, operand_types, comps) -> int:
    """Bytes a fusion actually READS: a parameter consumed only via
    (dynamic-)slice ops contributes its slice results, not its full size —
    scanned caches are stacked (L, …) tensors whose per-layer fusions read
    one layer."""
    mcalls = re.search(r"calls=%?([\w\.\-]+)", fusion_ln)
    if not mcalls or mcalls.group(1) not in comps:
        return sum(operand_types)
    lines = comps[mcalls.group(1)]
    # param index -> name, and name -> [consuming (op, result_bytes)]
    params = {}
    for ln in lines:
        mp = re.match(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\S+)\s+parameter\((\d+)\)", ln)
        if mp:
            params[int(mp.group(3))] = mp.group(1)
    total = 0
    for idx, full_bytes in enumerate(operand_types):
        pname = params.get(idx)
        if pname is None:
            total += full_bytes
            continue
        uses = []
        for ln in lines:
            m = _DEF_RE.match(ln)
            if not m:
                continue
            if re.search(rf"%{re.escape(pname)}\b", ln[m.end() - 1:]):
                uses.append((m.group(3), _type_bytes(m.group(2))))
        slice_sum = sum(b for _, b in uses)
        if uses and all(op in _SLICE_OPS for op, _ in uses) \
                and slice_sum < full_bytes:
            total += slice_sum      # big tensor, sliced reads only
        else:
            total += full_bytes
    return total


def hlo_cost(hlo_text: str) -> dict:
    """Trip-weighted {flops, bytes} for the per-device optimized module."""
    comps = _split_computations(hlo_text)
    trips = _find_while_trips(comps)
    weights = _computation_weights(comps, trips)

    flops = 0.0
    bytes_ = 0.0
    for cname, lines in comps.items():
        w = weights.get(cname, 0.0)
        if w <= 0:
            continue
        defs = _parse_defs(lines)
        for ln in lines:
            m = _DEF_RE.match(ln)
            if not m:
                continue
            op = m.group(3)
            if op in ("dot", "convolution"):
                flops += w * _dot_flops(ln, defs)
            if op in _SKIP_BYTES_OPS:
                continue
            rb = _type_bytes(m.group(2))
            operand_types, big = [], 0
            args = ln[m.end() - 1:]
            head = args.split("), ")[0]
            for om in _OPERAND_RE.findall(head):
                if om in defs:
                    b1 = _type_bytes(defs[om][0])
                    operand_types.append(b1)
                    big = max(big, b1)
            if op == "dynamic-update-slice" or "dynamic-update-slice" in m.group(1):
                # in-place update: the target buffer aliases the result —
                # real traffic is the updated slice + indices, not 2× the
                # full cache (XLA prints no aliasing info; subtract the
                # aliased pair).
                bytes_ += w * max(rb + sum(operand_types) - 2 * big, 0)
            elif op == "fusion":
                bytes_ += w * (rb + _fusion_read_bytes(ln, operand_types,
                                                       comps))
            else:
                bytes_ += w * (rb + sum(operand_types))
    return {"flops": float(flops), "bytes": float(bytes_),
            "while_trips": trips}


def memory_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    fields = ["argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"]
    out = {f: int(getattr(ma, f, 0)) for f in fields}
    out["total_hbm_bytes"] = (out["argument_size_in_bytes"] +
                              out["output_size_in_bytes"] +
                              out["temp_size_in_bytes"] -
                              out["alias_size_in_bytes"])
    return out


def cost_stats(compiled) -> dict:
    ca = compiled.cost_analysis()
    d = ca if isinstance(ca, dict) else (ca[0] if ca else {})
    return {k: float(v) for k, v in d.items()
            if k in ("flops", "bytes accessed", "transcendentals",
                     "utilization operand 0", "optimal_seconds")}
