import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: no `from __future__ import annotations` here — the XLA_FLAGS export
# above must stay the first executable statement of the module.

"""Multi-pod dry-run — lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent: for each cell
we build full-size ShapeDtypeStruct stand-ins (zero allocation), jit with
explicit in/out shardings on the production mesh, ``.lower().compile()``,
and record ``memory_analysis()`` / ``cost_analysis()`` / the collective
schedule parsed from the optimized HLO.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape decode_32k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun

The 512-device XLA flag above MUST precede every other import (jax locks
the device count at first init), which is why it is the first line of the
file and set nowhere else in the repo.
"""
import argparse
import dataclasses
import json
import math
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import get_config, ASSIGNED_ARCHS
from repro.core import CompressionPolicy
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (SHAPES, input_specs, serve_param_specs,
                                train_state_specs, shape_applicable)
from repro.serve.engine import make_serve_fns
from repro.sharding import partition as PT
from repro.train.optimizer import AdamWConfig
from repro.train.steps import TrainConfig, make_train_step

# Per-arch training knobs (activation memory / optimizer HBM management).
GIANT = {"llama3-405b", "kimi-k2-1t-a32b"}
ACCUM = {"llama3-405b": 64, "kimi-k2-1t-a32b": 32,
         "qwen2-7b": 2, "qwen3-4b": 2, "deepseek-v2-lite-16b": 4,
         "seamless-m4t-medium": 4, "mamba2-2.7b": 4, "zamba2-1.2b": 4}
# int8-moment block: must divide each param's (per-shard) last dim — kimi's
# kv_lora=512/16 shards to 32.
QBLOCK = {"kimi-k2-1t-a32b": 32}
# Chunked CE: never materialize (B, T, V) logits (see steps.chunked_cross_
# entropy).  512-token chunks keep the transient logits slice ≤ ~2 GiB/dev
# even at vocab 256k.
LOGITS_CHUNK = 512
# serve: FSDP the weights across the data axis for models that exceed
# HBM×TP alone
FSDP_SERVE = GIANT


def _train_cfgs(arch_id: str) -> TrainConfig:
    giant = arch_id in GIANT
    return TrainConfig(
        optimizer=AdamWConfig(quantized_state=giant,
                              qblock=QBLOCK.get(arch_id, 256)),
        accum_steps=ACCUM.get(arch_id, 1),
        logits_chunk=LOGITS_CHUNK,
        # bf16 accumulator for 1T-scale: halves the dominant state buffer
        accum_dtype=(jnp.bfloat16 if giant else jnp.float32),
    )


MOE_LOCAL_DISPATCH = {"deepseek-v2-lite-16b", "kimi-k2-1t-a32b"}


def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
               mode: str = "compressed", param_dtype=jnp.bfloat16):
    """Build + lower + compile one cell. Returns (compiled, meta)."""
    entry = get_config(arch_id)
    cfg = entry.full
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        return None, {"skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = input_specs(arch_id, shape_name)
    kind = cell["kind"]
    if arch_id in MOE_LOCAL_DISPATCH and kind != "train":
        # shard_map local-routing MoE, SERVE only (§Perf DP3): deepseek
        # prefill collectives 221→49 GiB, kimi prefill 5168→705 GiB and
        # HBM 52.4→20.2; at TRAIN the dense expert params would re-gather
        # over the data axis every layer (kimi 54.7→81.4 GiB, refuted).
        cfg = dataclasses.replace(cfg, moe_local_dispatch=True)

    with mesh, PT.active_mesh(mesh):
        if kind == "train":
            tcfg = _train_cfgs(arch_id)
            state_specs = train_state_specs(cfg, tcfg.optimizer, param_dtype)
            sspec = PT.make_train_state_specs(state_specs, mesh,
                                              PT.ShardingConfig(mode="train"))
            bspec = PT.make_data_specs(cell["batch"], mesh)
            step = make_train_step(cfg, tcfg)
            jf = jax.jit(
                step,
                in_shardings=(PT.to_named(sspec, mesh),
                              PT.to_named(bspec, mesh)),
                out_shardings=(PT.to_named(sspec, mesh), None),
                donate_argnums=(0,),
            )
            lowered = jf.lower(state_specs, cell["batch"])
        else:
            # Giants at DECODE: 2D-tiled compressed storage (§Perf D2) —
            # weights permanently resident (out/model × in/data), no
            # use-time weight collectives.  At PREFILL the activations are
            # large and 2D-TP partial sums cost more than the compressed-
            # byte gather (measured 8.9 TiB vs 45 GiB; §Perf D2-refuted
            # branch), so prefill keeps FSDP planes + D1 degather.
            tiles = 16 if (arch_id in FSDP_SERVE and mode == "compressed"
                           and kind == "decode") else 0
            policy = CompressionPolicy(mode=mode, tiles=tiles)
            # weight-axis size (pod×model): the fused tile choice divides
            # the per-shard out dim so lowering takes the shard-mapped
            # fused megakernel paths, not the two-step fallback
            wshards = 1
            for a in ("pod", "model"):
                if a in mesh.axis_names:
                    wshards *= mesh.shape[a]
            pspecs, lut = serve_param_specs(cfg, policy, param_dtype,
                                            model_shards=wshards)
            # NOTE(§Perf, refuted): pod_in_fsdp=False (weights replicated
            # across pods) raised kimi/llama multi-pod prefill HBM by
            # 2-4%, so pod-extended FSDP stays on for serve.
            scfg = PT.ShardingConfig(
                mode="serve", fsdp_weights=arch_id in FSDP_SERVE)
            pshard = PT.to_named(PT.make_param_specs(pspecs, mesh, scfg),
                                 mesh)
            cshard = PT.to_named(PT.make_cache_specs(cell["caches"], mesh),
                                 mesh)
            bshard = PT.to_named(PT.make_data_specs(cell["batch"], mesh),
                                 mesh)
            lutshard = (jax.NamedSharding(mesh, jax.sharding.PartitionSpec())
                        if lut is not None else None)
            # raw closures: the dry-run applies its own pjit shardings
            prefill, decode = make_serve_fns(cfg, jit=False)
            if kind == "prefill":
                out_cshard = PT.to_named(
                    PT.make_cache_specs(cell.get("out_caches",
                                                 cell["caches"]), mesh), mesh)
                jf = jax.jit(
                    prefill,
                    in_shardings=(pshard, lutshard, bshard, cshard),
                    out_shardings=(None, out_cshard),
                    donate_argnums=(3,),
                )
                lowered = jf.lower(pspecs, lut, cell["batch"], cell["caches"])
            else:
                posshard = jax.NamedSharding(mesh,
                                             jax.sharding.PartitionSpec())
                jf = jax.jit(
                    decode,
                    in_shardings=(pshard, lutshard, bshard["tokens"],
                                  cshard, posshard),
                    out_shardings=(None, cshard),
                    donate_argnums=(3,),
                )
                lowered = jf.lower(pspecs, lut, cell["batch"]["tokens"],
                                   cell["caches"], cell["pos"])
        compiled = lowered.compile()
    meta = {"mesh": "multi" if multi_pod else "single",
            "kind": kind, "mode": mode}
    if kind != "train" and mode == "compressed":
        budget = _residency_budget(pspecs, lut, cell["caches"])
        if budget is not None:
            meta["residency_budget"] = budget.summary()
    return compiled, meta


def _residency_budget(pspecs, lut, caches, budget_mib: int = 4096):
    """Tiered-residency budget math for one serve cell (spec trees only —
    no allocation): how much of the paper's 4 GiB edge budget is left for
    the HBM expert cache once non-expert weights + KV + activation
    headroom are pinned.  None for non-MoE archs."""
    from repro.core.policy import device_budget
    try:
        experts = pspecs["blocks"]["moe"]["experts"]
    except (KeyError, TypeError):
        return None

    def nb(tree):
        return sum(math.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(tree)
                   if hasattr(l, "shape") and hasattr(l, "dtype"))

    expert_bytes = nb(experts)
    resident = nb(pspecs) - expert_bytes + (nb(lut) if lut is not None
                                            else 0)
    return device_budget(budget_mib * 2**20, expert_bytes=expert_bytes,
                         resident_bytes=resident, kv_bytes=nb(caches),
                         act_bytes=64 * 2**20)


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             mode: str = "compressed", keep_hlo: bool = False) -> dict:
    t0 = time.monotonic()
    rec = {"arch": arch_id, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single", "mode": mode}
    try:
        compiled, meta = lower_cell(arch_id, shape_name,
                                    multi_pod=multi_pod, mode=mode)
        if compiled is None:
            rec.update(ok=True, **meta)
            rec["wall_s"] = round(time.monotonic() - t0, 1)
            return rec
        rec["memory"] = hlo_stats.memory_stats(compiled)
        if "residency_budget" in meta:
            rec["residency_budget"] = meta["residency_budget"]
        rec["cost"] = hlo_stats.cost_stats(compiled)
        hlo = compiled.as_text()
        # trip-weighted FLOP/byte model (XLA's cost_analysis counts while
        # bodies once — ~8000x under for scanned+accumulated training)
        rec["hlo_cost"] = hlo_stats.hlo_cost(hlo)
        rec["collectives"] = hlo_stats.collective_stats(hlo).as_dict()
        rec["hlo_chars"] = len(hlo)
        rec["ok"] = True
        if keep_hlo:
            rec["hlo_text"] = hlo
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(time.monotonic() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--mode", default="compressed",
                    choices=["dense", "quant", "compressed"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                fn = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_kind}__{args.mode}.json")
                if os.path.exists(fn):
                    with open(fn) as f:
                        if json.load(f).get("ok"):
                            print(f"[skip cached] {fn}")
                            continue
                rec = run_cell(arch, shape, multi_pod=(mesh_kind == "multi"),
                               mode=args.mode)
                with open(fn, "w") as f:
                    json.dump(rec, f, indent=1)
                status = ("OK" if rec.get("ok") else "FAIL") + \
                    (" (skipped: " + rec["skipped"] + ")"
                     if "skipped" in rec else "")
                mem = rec.get("memory", {}).get("total_hbm_bytes", 0)
                print(f"[{status}] {arch} {shape} {mesh_kind} "
                      f"hbm/dev={mem/2**30:.2f}GiB wall={rec['wall_s']}s",
                      flush=True)
                if rec.get("residency_budget"):
                    print("  " + rec["residency_budget"], flush=True)
                if not rec.get("ok"):
                    print(rec.get("error"), flush=True)


if __name__ == "__main__":
    main()
