"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS for 512 host devices *before* calling it; tests and benches see
the default single device.
"""
from __future__ import annotations

import jax

AXIS_POD = "pod"
AXIS_DATA = "data"
AXIS_MODEL = "model"


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = (AXIS_POD, AXIS_DATA, AXIS_MODEL) if multi_pod else (AXIS_DATA,
                                                                AXIS_MODEL)
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh (elastic restart targets, tests)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU tests/examples."""
    return jax.make_mesh((1, 1), (AXIS_DATA, AXIS_MODEL))


def data_axes(mesh) -> tuple:
    """Axes that carry the batch (pod extends data across pods)."""
    return tuple(a for a in (AXIS_POD, AXIS_DATA) if a in mesh.axis_names)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
