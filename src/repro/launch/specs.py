"""Dry-run spec planning — ShapeDtypeStruct stand-ins, zero allocation.

``param_specs(cfg, mode)`` builds the full-model parameter spec tree via
``jax.eval_shape`` over the real initializers (so dry-run shapes can never
drift from the real model), then rewrites policy-selected leaves into
QuantLinear/PackedLinear spec containers for the serve modes.

``input_specs(arch_id, shape_name)`` yields the four assigned input-shape
cells; serve shapes include the KV-cache spec trees.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import CompressionPolicy
from repro.core.compressed import (planned_packed_specs, planned_quant_specs,
                                   planned_tiled_specs, lut_spec)
from repro.models import lm as LM
from repro.models import encdec as ED
from repro.train.optimizer import AdamWConfig, QMoment


# The four assigned LM shapes: (name, seq_len, global_batch, kind)
SHAPES = {
    "train_4k":    dict(seq=4_096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32_768, batch=32, kind="prefill"),
    "decode_32k":  dict(seq=32_768, batch=128, kind="decode"),
    "long_500k":   dict(seq=524_288, batch=1, kind="decode"),
}


def shape_applicable(cfg, shape_name: str) -> tuple[bool, str]:
    """Per DESIGN.md §Arch-applicability."""
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return False, "long_500k skipped: full quadratic attention"
    return True, ""


def dense_param_specs(cfg, dtype=jnp.bfloat16) -> Any:
    if cfg.family == "encdec":
        fn = partial(ED.init_encdec, cfg=cfg, dtype=dtype)
    else:
        fn = partial(LM.init_lm, cfg=cfg, dtype=dtype)
    return jax.eval_shape(lambda: fn(jax.random.PRNGKey(0)))


def serve_param_specs(cfg, policy: CompressionPolicy,
                      dtype=jnp.bfloat16,
                      model_shards: int = 1) -> tuple[Any, Any]:
    """(param specs with containers, lut spec or None).

    ``model_shards``: intended weight-axis size (model×pod) of the serving
    mesh — planned planes then carry the fused tile-major layout whose
    tiles divide the per-shard out dim (``choose_fused_tiles(shards=…)``),
    exactly like ``engine.build_serve_params(model_shards=…)``, so the
    dry-run lowers the fused megakernel paths, not the two-step fallback.
    Stacked expert leaves keep stacked PackedLinear planes (never 2D-TP
    column tiles) so the grouped expert megakernel path stays reachable.
    """
    from repro.core.blocked_codec import choose_fused_tiles

    dense = dense_param_specs(cfg, dtype)
    flat, treedef = jax.tree_util.tree_flatten_with_path(dense)
    out, any_compressed = [], False
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if leaf.ndim < 2:
            out.append(leaf)
            continue
        shape2 = tuple(leaf.shape[-2:])
        lead = tuple(leaf.shape[:-2])
        act = policy.action(name, shape2)
        if act == "quant":
            out.append(planned_quant_specs(shape2, stacked=lead))
        elif act == "compressed":
            any_compressed = True
            if (policy.tiles > 1 and shape2[1] % policy.tiles == 0
                    and "experts" not in name):
                in_t = shape2[1] // policy.tiles
                picked = choose_fused_tiles((shape2[0], in_t),
                                            policy.block_weights,
                                            shards=(model_shards, 1))
                tn, tk = picked[:2] if picked else (0, 0)
                out.append(planned_tiled_specs(
                    shape2, policy.tiles, stacked=lead,
                    block_weights=policy.block_weights,
                    tile_n=tn, tile_k=tk))
            else:
                from repro.sharding.partition import (clean_keystr,
                                                      is_row_parallel)
                picked = choose_fused_tiles(shape2, policy.block_weights,
                                            shards=(model_shards, 1))
                tn, tk = picked[:2] if picked else (0, 0)
                pl = planned_packed_specs(
                    shape2, stacked=lead,
                    block_weights=policy.block_weights,
                    tile_n=tn, tile_k=tk)
                pl.row_parallel = is_row_parallel(clean_keystr(name))
                out.append(pl)
        else:
            out.append(leaf)
    lut = lut_spec() if any_compressed else None
    return treedef.unflatten(out), lut


def train_state_specs(cfg, tcfg_optimizer: AdamWConfig,
                      param_dtype=jnp.bfloat16) -> Any:
    """{"params", "opt"} spec tree, honoring int8 optimizer state."""
    from repro.train.optimizer import moment_block, quantizable
    params = dense_param_specs(cfg, param_dtype)

    def mu(p):
        if quantizable(p, tcfg_optimizer):
            *lead, last = p.shape
            b = moment_block(last, tcfg_optimizer.qblock)
            q = jax.ShapeDtypeStruct((*lead, last // b, b), jnp.uint8)
            s = jax.ShapeDtypeStruct((*lead, last // b, 1), jnp.float32)
            return {"m": QMoment(q, s, s), "v": QMoment(q, s, s)}
        z = jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return {"m": z, "v": z}

    opt = {"mu": jax.tree_util.tree_map(mu, params),
           "step": jax.ShapeDtypeStruct((), jnp.int32)}
    return {"params": params, "opt": opt}


def cache_specs_for(cfg, batch: int, max_len: int,
                    dtype=jnp.bfloat16) -> Any:
    if cfg.family == "encdec":
        hd = cfg.resolved_head_dim
        enc_len = _enc_len(cfg, max_len)
        sds = jax.ShapeDtypeStruct
        self_c = jax.eval_shape(
            lambda: ED.init_dec_caches(cfg, batch, max_len, dtype))
        ekv = sds((cfg.decoder_layers, batch, enc_len, cfg.n_kv_heads, hd),
                  dtype)
        return {"self": self_c, "enc_k": ekv, "enc_v": ekv}
    return jax.eval_shape(lambda: LM.init_caches(cfg, batch, max_len, dtype))


def _enc_len(cfg, seq: int) -> int:
    return seq  # audio frames length == assigned seq_len


def input_specs(arch_id: str, shape_name: str,
                dtype=jnp.bfloat16) -> dict:
    """Batch (and cache) ShapeDtypeStructs for one (arch × shape) cell.

    Returns {"kind", "batch": {...}, "caches": ... , "pos": ...} matching
    the step function the dry-run lowers.
    """
    entry = get_config(arch_id)
    cfg = entry.full
    sh = SHAPES[shape_name]
    seq, batch, kind = sh["seq"], sh["batch"], sh["kind"]
    sds = jax.ShapeDtypeStruct
    i32 = jnp.int32

    if kind == "train":
        if cfg.family == "encdec":
            b = {"enc_embeds": sds((batch, seq, cfg.d_model), dtype),
                 "tokens": sds((batch, seq), i32),
                 "labels": sds((batch, seq), i32)}
        elif cfg.family == "vlm":
            text = seq - cfg.n_patches
            b = {"tokens": sds((batch, text), i32),
                 "embeds": sds((batch, cfg.n_patches, cfg.d_model), dtype),
                 "labels": sds((batch, text), i32)}
        else:
            b = {"tokens": sds((batch, seq), i32),
                 "labels": sds((batch, seq), i32)}
        return {"kind": "train", "batch": b}

    if kind == "prefill":
        caches = cache_specs_for(cfg, batch, seq, dtype)
        out_caches = caches
        if cfg.family == "encdec":
            b = {"enc_embeds": sds((batch, seq, cfg.d_model), dtype),
                 "tokens": sds((batch, 1), i32)}
            caches = {"self": caches["self"]}  # enc_kv produced by prefill
        elif cfg.family == "vlm":
            b = {"tokens": sds((batch, seq - cfg.n_patches), i32),
                 "embeds": sds((batch, cfg.n_patches, cfg.d_model), dtype)}
        else:
            b = {"tokens": sds((batch, seq), i32)}
        return {"kind": "prefill", "batch": b, "caches": caches,
                "out_caches": out_caches}

    # decode: one new token against a seq-length cache
    caches = cache_specs_for(cfg, batch, seq, dtype)
    b = {"tokens": sds((batch, 1), i32)}
    return {"kind": "decode", "batch": b, "caches": caches,
            "pos": sds((), i32)}
