"""Training launcher — single-host driver with the production code paths.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 100 --batch 16 --seq 64 [--smoke/--full] [--mesh host]

On this container it runs the smoke config on the (1,1) host mesh; on a
real pod the same driver takes ``--mesh single|multi`` and the full config
(the dry-run proves those lower+compile).  All production features are on
the path: sharded train state, chunked CE, gradient accumulation,
fault-tolerant loop with atomic checkpoints, optional int8 optimizer
state and gradient compression.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import lm as LM
from repro.models import encdec as ED
from repro.sharding import partition as PT
from repro.train.data import DataConfig, DataPipeline
from repro.train.fault import FaultConfig, FaultTolerantLoop
from repro.train.optimizer import AdamWConfig
from repro.train.steps import TrainConfig, make_train_step, init_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--full", action="store_true",
                    help="full config (pod-scale; smoke by default)")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--quantized-opt", action="store_true")
    ap.add_argument("--logits-chunk", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    entry = get_config(args.arch)
    cfg = entry.full if args.full else entry.smoke
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=args.mesh == "multi"))

    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                              total_steps=args.steps,
                              quantized_state=args.quantized_opt),
        accum_steps=args.accum,
        grad_compression=args.grad_compression,
        logits_chunk=args.logits_chunk,
    )
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                   batch=args.batch, seq_len=args.seq))

    if cfg.family == "encdec":
        raise SystemExit("use examples/ for enc-dec; LM families here")
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    state = init_train_state(params, tcfg)

    with mesh, PT.active_mesh(mesh):
        sspec = PT.make_train_state_specs(state, mesh,
                                          PT.ShardingConfig(mode="train"))
        sshard = PT.to_named(sspec, mesh)
        # distinct buffers per leaf: jnp.zeros constant-caching would alias
        # the m/v moments and break donation ("donate same buffer twice")
        state = jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True),
                                       state)
        state = jax.device_put(state, sshard)
        step = jax.jit(make_train_step(cfg, tcfg),
                       in_shardings=(sshard, None),
                       out_shardings=(sshard, None),
                       donate_argnums=(0,))

        def on_metrics(s, m):
            if s % 10 == 0 or s == 1:
                print(f"step {s:5d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.3f} "
                      f"lr {float(m['lr']):.2e}", flush=True)

        loop = FaultTolerantLoop(
            step, state, data,
            FaultConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
            state_shardings=sshard, on_metrics=on_metrics)
        loop.maybe_resume()
        loop.run(args.steps)
    print("done.")


if __name__ == "__main__":
    main()
