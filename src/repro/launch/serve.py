"""Serving launcher — compress a model and serve batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --mode compressed --batch 4 --max-new 16

Host-mesh driver over the same (prefill, decode) step functions the
multi-pod dry-run lowers for the production meshes.

Sharded serving (``--mesh DATA,MODEL``, e.g. with
``XLA_FLAGS=--xla_force_host_platform_device_count=8 ... --mesh 2,4``):
params are placed with the partition rules, the step functions are traced
under the mesh, and every compressed matmul dispatches through the
shard-mapped fused decode→dequant→matmul path — a single traced program
per phase, no dense per-device weight materialization (the dispatch
summary printed at the end proves which paths ran).  ``--tiles N`` stores
eligible weights as 2D-TP column tiles (TiledPackedLinear).
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

import dataclasses

from repro.configs import get_config
from repro.core import CompressionPolicy
from repro.kernels import ops
from repro.models import lm as LM
from repro.serve.engine import build_serve_params, make_serve_fns
from repro.serve.resilience import ResiliencePolicy, ResilientEngine
from repro.sharding import partition as PT
from repro.train.data import DataConfig, DataPipeline


def _parse_mesh(spec: str | None):
    """'2,4' -> Mesh((2, 4), ('data', 'model')); None -> no mesh."""
    if not spec:
        return None
    shape = tuple(int(s) for s in spec.split(","))
    assert len(shape) == 2, f"--mesh wants DATA,MODEL, got {spec!r}"
    ndev = jax.device_count()
    need = shape[0] * shape[1]
    assert need <= ndev, (f"--mesh {spec} needs {need} devices, have {ndev} "
                          f"(set XLA_FLAGS=--xla_force_host_platform_"
                          f"device_count={need} for CPU)")
    return jax.make_mesh(shape, ("data", "model"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--mode", default="compressed",
                    choices=["dense", "quant", "compressed"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mesh", default=None,
                    help="DATA,MODEL mesh shape for sharded serving")
    ap.add_argument("--tiles", type=int, default=0,
                    help="2D-TP column tiles for compressed weights "
                         "(TiledPackedLinear; 0 = plain PackedLinear)")
    ap.add_argument("--verify", default="off",
                    choices=["off", "fast", "full"],
                    help="integrity gate before serving: re-hash the "
                         "packed artifact against its manifest (fast = "
                         "sampled digests, full = every byte) plus the "
                         "device-side invariant check; corrupt leaves "
                         "refuse to serve (core/integrity.py)")
    args = ap.parse_args()

    mesh = _parse_mesh(args.mesh)
    model_shards = mesh.shape["model"] if mesh is not None else 1

    cfg = get_config(args.arch).smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                   batch=args.batch,
                                   seq_len=args.prompt_len))
    if args.mode == "dense":
        st, sp, lut = None, params, None
    else:
        st = build_serve_params(
            params, CompressionPolicy(mode=args.mode, min_weight_size=1024,
                                      tiles=args.tiles),
            model_shards=model_shards)
        sp, lut = st.params, st.lut
        print(f"{args.mode} weights: {sum(st.stats.values())/2**20:.2f} MiB")

    if mesh is not None:
        # place params per the partition rules; lut replicates
        specs = PT.make_param_specs(sp, mesh, PT.ShardingConfig(mode="serve"))
        sp = jax.device_put(sp, PT.to_named(specs, mesh))
        if lut is not None:
            lut = jax.device_put(
                lut, jax.NamedSharding(mesh, jax.sharding.PartitionSpec()))
        print(f"mesh: {dict(mesh.shape)}")

    rengine = None
    if st is not None:
        # integrity gate (manifest re-hash + device invariants) runs at
        # construction when --verify is on; corrupt leaves raise
        # IntegrityError naming themselves instead of serving garbage.
        rengine = ResilientEngine(
            cfg, dataclasses.replace(st, params=sp, lut=lut),
            policy=ResiliencePolicy(verify=args.verify), mesh=mesh)
        if args.verify != "off":
            print(rengine.verify_report.summary())
            print(rengine.invariant_report.summary())

    toks = data.batch_at(0)["tokens"]
    b, t0 = toks.shape
    caches = LM.init_caches(cfg, b, t0 + args.max_new, dtype=jnp.float32)
    prefill, decode = make_serve_fns(cfg, mesh=mesh)  # jitted, cached per
    ops.DISPATCH_COUNTS.clear()                       # (config, mesh)

    t = time.perf_counter()
    logits, caches = prefill(sp, lut, {"tokens": toks}, caches)
    jax.block_until_ready(logits)
    print(f"prefill: {1e3*(time.perf_counter()-t):.1f} ms")

    tok = jnp.argmax(logits, -1)[:, None].astype(toks.dtype)
    outs = [tok]
    t = time.perf_counter()
    for i in range(args.max_new - 1):
        logits, caches = decode(sp, lut, tok, caches, t0 + i)
        tok = jnp.argmax(logits, -1)[:, None].astype(toks.dtype)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t
    print(f"decode: {args.max_new-1} steps in {1e3*dt:.1f} ms "
          f"({b*(args.max_new-1)/dt:.1f} tok/s)")
    if args.mode == "compressed":
        print("matmul dispatch:", dict(ops.DISPATCH_COUNTS))
    if rengine is not None:
        print("health:", rengine.health())
    print("sample:", np.concatenate([np.asarray(o) for o in outs], 1)[0].tolist())


if __name__ == "__main__":
    main()
