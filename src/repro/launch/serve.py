"""Serving launcher — compress a model and serve a request trace.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --mode compressed --batch 8 --slots 3 --stagger 2 --max-new 16

Drives the request-level API: each of ``--batch`` prompts is submitted as
a ``serve.Request`` with staggered arrivals (``--stagger`` engine steps
apart), served by the continuous-batching ``serve.Engine`` over a paged
KV pool of ``--slots`` decode slots — requests join and leave the running
decode loop per tick, and the occupancy/throughput summary printed at the
end shows the overlap.  Overload knobs: ``--max-queue`` bounds the
admission queue (overflow sheds per ``--shed-policy``) and
``--request-ttl`` expires requests that wait or run too long — overload
always surfaces as accounted-for completions ('shed'/'deadline'), and the
queue-peak/shed/preempt/quarantine counters print with the summary.  With
compression on, the engine comes from
``ResilientEngine.scheduler()``: every jitted prefill/decode step walks
the retry/degradation ladder and the health snapshot is printed.

Sharded serving (``--mesh DATA,MODEL``, e.g. with
``XLA_FLAGS=--xla_force_host_platform_device_count=8 ... --mesh 2,4``):
params are placed with the partition rules, the step functions are traced
under the mesh, and every compressed matmul dispatches through the
shard-mapped fused decode→dequant→matmul path — a single traced program
per phase, no dense per-device weight materialization (the dispatch
summary printed at the end proves which paths ran).  ``--tiles N`` stores
eligible weights as 2D-TP column tiles (TiledPackedLinear).

Tiered expert residency (``--residency tiered``, compressed MoE archs,
mesh-less): compressed expert planes back off to host RAM and an HBM
cache of ``--expert-cache-mib`` (0 = auto from ``--hbm-budget-mib`` via
``core.policy.device_budget`` — the paper's 4–8 GB edge budget) serves
the grouped kernel, with routing-aware one-layer-ahead prefetch
(serve/residency.py, docs/residency.md).  Outputs are bitwise-equal to
fully-resident serving; the summary adds hit/miss/prefetch/eviction/
bytes-fetched counters alongside the resilience health snapshot.

Runtime memory pressure (``--pressure-trace step|spike|ramp|oscillate``):
replays a seeded budget trace (``testing.faults.pressure_trace``) against
a ``serve.governor.MemoryGovernor`` attached to the engine — the budget
moves per step and the governor walks the reclaim/regrow ladder (trim
expert cache → shrink KV pool/preempt → tighten admission → refuse new
work as ``finished='pressure'``), with ``--min-budget-mib`` as the
operator refusal floor.  The end-of-run summary prints the applied plan,
plan-change count, and per-rung reclaim latency (docs/serving.md).
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

import dataclasses

from repro.configs import get_config
from repro.core import CompressionPolicy
from repro.kernels import ops
from repro.models import lm as LM
from repro.serve.context import ServeContext
from repro.serve.engine import build_serve_params
from repro.serve.resilience import ResiliencePolicy, ResilientEngine
from repro.serve.scheduler import Engine, Request
from repro.sharding import partition as PT
from repro.train.data import DataConfig, DataPipeline


def _parse_mesh(spec: str | None):
    """'2,4' -> Mesh((2, 4), ('data', 'model')); None -> no mesh."""
    if not spec:
        return None
    shape = tuple(int(s) for s in spec.split(","))
    assert len(shape) == 2, f"--mesh wants DATA,MODEL, got {spec!r}"
    ndev = jax.device_count()
    need = shape[0] * shape[1]
    assert need <= ndev, (f"--mesh {spec} needs {need} devices, have {ndev} "
                          f"(set XLA_FLAGS=--xla_force_host_platform_"
                          f"device_count={need} for CPU)")
    return jax.make_mesh(shape, ("data", "model"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--mode", default="compressed",
                    choices=["dense", "quant", "compressed"])
    ap.add_argument("--batch", type=int, default=4,
                    help="number of requests in the trace")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=3,
                    help="decode slots in the paged-KV pool (requests "
                         "beyond this queue and join as slots free)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page")
    ap.add_argument("--stagger", type=int, default=2,
                    help="engine steps between request arrivals "
                         "(0 = all at once)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the admission queue: overflow sheds a "
                         "request per --shed-policy as a "
                         "Completion(finished='shed') (default: unbounded)")
    ap.add_argument("--shed-policy", default="reject-new",
                    choices=["reject-new", "drop-oldest"],
                    help="who sheds when the bounded queue overflows")
    ap.add_argument("--request-ttl", type=int, default=None,
                    help="engine-wide TTL in engine steps from submit; "
                         "expired requests complete with "
                         "finished='deadline' (default: no TTL)")
    ap.add_argument("--mesh", default=None,
                    help="DATA,MODEL mesh shape for sharded serving")
    ap.add_argument("--tiles", type=int, default=0,
                    help="2D-TP column tiles for compressed weights "
                         "(TiledPackedLinear; 0 = plain PackedLinear)")
    ap.add_argument("--verify", default="off",
                    choices=["off", "fast", "full"],
                    help="integrity gate before serving: re-hash the "
                         "packed artifact against its manifest (fast = "
                         "sampled digests, full = every byte) plus the "
                         "device-side invariant check; corrupt leaves "
                         "refuse to serve (core/integrity.py)")
    ap.add_argument("--residency", default="hbm",
                    choices=["hbm", "tiered"],
                    help="expert residency: 'hbm' keeps every compressed "
                         "expert on device; 'tiered' backs them in host "
                         "RAM with a routing-aware HBM cache "
                         "(serve/residency.py; compressed MoE only, "
                         "mesh-less)")
    ap.add_argument("--expert-cache-mib", type=int, default=0,
                    help="HBM expert-cache size for --residency tiered "
                         "(0 = auto from --hbm-budget-mib via "
                         "core.policy.device_budget)")
    ap.add_argument("--hbm-budget-mib", type=int, default=4096,
                    help="device memory budget used to auto-size the "
                         "expert cache (paper target: 4-8 GB edge)")
    ap.add_argument("--pressure-trace", default="none",
                    choices=["none", "step", "spike", "ramp", "oscillate"],
                    help="replay a seeded runtime memory-pressure trace "
                         "against the serving engine: the budget moves "
                         "per step and serve.governor.MemoryGovernor "
                         "walks the reclaim/regrow ladder "
                         "(testing.faults.pressure_trace; seeded via "
                         "REPRO_FAULT_SEED)")
    ap.add_argument("--pressure-low-mib", type=int, default=0,
                    help="the trace's low watermark (0 = auto: 60%% of "
                         "--hbm-budget-mib)")
    ap.add_argument("--min-budget-mib", type=int, default=0,
                    help="operator floor for the governor: below this it "
                         "refuses new work (finished='pressure') instead "
                         "of reclaiming further (0 = the computed "
                         "min_viable floor only)")
    args = ap.parse_args()

    mesh = _parse_mesh(args.mesh)
    model_shards = mesh.shape["model"] if mesh is not None else 1

    cfg = get_config(args.arch).smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                   batch=args.batch,
                                   seq_len=args.prompt_len))
    if args.mode == "dense":
        st, sp, lut = None, params, None
    else:
        st = build_serve_params(
            params, CompressionPolicy(mode=args.mode, min_weight_size=1024,
                                      tiles=args.tiles),
            model_shards=model_shards)
        sp, lut = st.params, st.lut
        print(f"{args.mode} weights: {sum(st.stats.values())/2**20:.2f} MiB")

    if mesh is not None:
        # place params per the partition rules; lut replicates
        specs = PT.make_param_specs(sp, mesh, PT.ShardingConfig(mode="serve"))
        sp = jax.device_put(sp, PT.to_named(specs, mesh))
        if lut is not None:
            lut = jax.device_put(
                lut, jax.NamedSharding(mesh, jax.sharding.PartitionSpec()))
        print(f"mesh: {dict(mesh.shape)}")

    max_len = args.prompt_len + args.max_new

    def _tree_bytes(t):
        return sum(int(l.nbytes) for l in jax.tree_util.tree_leaves(t)
                   if hasattr(l, "nbytes"))

    def _device_budget(expert_bytes: int) -> "object":
        from repro.core.policy import device_budget
        from repro.serve.kv_cache import PagedKVPool
        resident_bytes = _tree_bytes(sp) - expert_bytes + \
            (int(lut.nbytes) if lut is not None else 0)
        probe_pool = PagedKVPool(cfg, args.slots, max_len,
                                 page_size=args.page_size)
        kv_bytes = _tree_bytes(probe_pool.pages)
        return device_budget(args.hbm_budget_mib * 2**20,
                             expert_bytes=expert_bytes,
                             resident_bytes=resident_bytes,
                             kv_bytes=kv_bytes,
                             act_bytes=64 * 2**20)

    budget = None
    residency = None
    if args.residency == "tiered":
        # Tiered expert residency: compressed expert planes back off to
        # host RAM; an HBM cache sized by the device budget serves the
        # grouped kernel (serve/residency.py).  Compressed MoE, mesh-less.
        from repro.serve.residency import ResidencyManager
        assert args.mode == "compressed", \
            "--residency tiered requires --mode compressed"
        assert mesh is None, "--residency tiered is single-device (no --mesh)"

        budget = _device_budget(_tree_bytes(sp["blocks"]["moe"]["experts"]))
        cache_bytes = (args.expert_cache_mib * 2**20
                       if args.expert_cache_mib > 0
                       else budget.expert_cache_bytes)
        st = dataclasses.replace(st, params=sp, lut=lut)
        residency = ResidencyManager(st, cfg, cache_bytes=cache_bytes)
        # summary(expert_cache_used=...) surfaces the overshoot when the
        # granted budget was too small and the cache clamped to its
        # one-expert-per-layer floor — never silently hidden
        used = (residency.capacity * residency.n_layers
                * residency.bytes_per_expert)
        print(budget.summary(expert_cache_used=used))
        print(f"expert cache: {residency.capacity}/{residency.n_experts} "
              f"experts/layer x {residency.n_layers} layers "
              f"({used / 2**20:.2f} MiB of "
              f"{cache_bytes / 2**20:.2f} MiB granted)")

    governor = None
    if args.pressure_trace != "none":
        from repro.serve.governor import MemoryGovernor
        from repro.testing.faults import pressure_trace
        if budget is None:
            budget = _device_budget(0)
        low = (args.pressure_low_mib * 2**20 if args.pressure_low_mib > 0
               else int(0.6 * args.hbm_budget_mib * 2**20))
        trace = pressure_trace(args.pressure_trace,
                               boot_bytes=budget.budget_bytes,
                               low_bytes=low, n_steps=64)
        state = {"i": 0}

        def poll():
            i = min(state["i"], len(trace) - 1)
            state["i"] += 1
            return trace[i]

        governor = MemoryGovernor(
            budget, poll=poll,
            min_budget_bytes=(args.min_budget_mib * 2**20
                              if args.min_budget_mib > 0 else None))
        print(f"pressure trace: {args.pressure_trace} "
              f"({budget.budget_bytes / 2**20:.0f} -> {low / 2**20:.0f} MiB "
              f"low watermark over {len(trace)} steps)")
    if st is not None:
        # integrity gate (manifest re-hash + device invariants) runs at
        # construction when --verify is on; corrupt leaves raise
        # IntegrityError naming themselves instead of serving garbage.
        rengine = ResilientEngine(
            cfg, dataclasses.replace(st, params=sp, lut=lut),
            policy=ResiliencePolicy(verify=args.verify), mesh=mesh,
            residency=residency)
        if args.verify != "off":
            print(rengine.verify_report.summary())
            print(rengine.invariant_report.summary())
        eng = rengine.scheduler(n_slots=args.slots, max_len=max_len,
                                page_size=args.page_size,
                                max_queue=args.max_queue,
                                shed_policy=args.shed_policy,
                                request_ttl=args.request_ttl,
                                governor=governor)
    else:
        rengine = None
        eng = Engine(ServeContext(cfg=cfg, mesh=mesh, lut=lut), sp,
                     n_slots=args.slots, max_len=max_len,
                     page_size=args.page_size, max_queue=args.max_queue,
                     shed_policy=args.shed_policy,
                     request_ttl=args.request_ttl, governor=governor)

    toks = np.asarray(data.batch_at(0)["tokens"])
    arrivals = [i * args.stagger for i in range(args.batch)]
    ops.DISPATCH_COUNTS.clear()

    t = time.perf_counter()
    submitted = 0
    while submitted < args.batch or eng.health()["occupied"] \
            or eng.health()["queued"]:
        while submitted < args.batch and eng.steps >= arrivals[submitted]:
            eng.submit(Request(tokens=toks[submitted],
                               max_new=args.max_new, rid=submitted))
            submitted += 1
        eng.step()
    jax.block_until_ready(eng.pool.pages)
    dt = time.perf_counter() - t

    h = eng.health()
    n_tok = sum(c.n_generated for c in eng.completions)
    print(f"served {h['completed']} requests / {n_tok} tokens in "
          f"{1e3*dt:.1f} ms ({n_tok/dt:.1f} tok/s) over {h['steps']} steps")
    print(f"occupancy: mean {h['occupancy_mean']:.2f} "
          f"max {h['occupancy_max']} of {args.slots} slots; "
          f"joined mid-decode: {h['joined_mid_decode']}")
    print(f"overload: queue_peak {h['queue_peak']} shed {h['shed']} "
          f"expired {h['expired']} preempted {h['preempted']} "
          f"quarantined {h['quarantined']} resumed {h['resumed']}")
    reasons = {}
    for c in eng.completions:
        reasons[c.finished] = reasons.get(c.finished, 0) + 1
    print("completions by reason:", reasons)
    if args.mode == "compressed":
        print("matmul dispatch:", dict(ops.DISPATCH_COUNTS))
    if rengine is not None:
        print("health:", rengine.health())
    if rengine is not None and rengine.residency is not None:
        r = rengine.residency.snapshot()
        print(f"residency: hits {r['hit']} (+{r['prefetch_hit']} prefetch) "
              f"misses {r['miss']} evictions {r['evict']} "
              f"fetched {r['bytes_fetched']/2**20:.2f} MiB "
              f"hit_rate {r['hit_rate']} prefetch_hit_rate "
              f"{r['prefetch_hit_rate']} stall {r['stall_s']:.3f}s")
    if governor is not None:
        s = governor.snapshot()
        print(f"pressure: plan_changes {s['plan_changes']} "
              f"refusing {s['refusing']} plan {s['plan']} "
              f"rung_latency_s {s['rung_latency_s']}")
    by_rid = {c.rid: c for c in eng.completions}
    print("sample:", by_rid[0].tokens[args.prompt_len:].tolist())
    eng.close()       # stop the residency prefetch worker (no leaked
    # threads — asserted in tests; see Engine.close)


if __name__ == "__main__":
    main()
