"""Serving launcher — compress a model and serve batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --mode compressed --batch 4 --max-new 16

Host-mesh driver over the same (prefill, decode) step functions the
multi-pod dry-run lowers for the production meshes.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import CompressionPolicy
from repro.models import lm as LM
from repro.serve.engine import build_serve_params, make_serve_fns
from repro.train.data import DataConfig, DataPipeline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--mode", default="compressed",
                    choices=["dense", "quant", "compressed"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                   batch=args.batch,
                                   seq_len=args.prompt_len))
    if args.mode == "dense":
        sp, lut = params, None
    else:
        st = build_serve_params(params, CompressionPolicy(
            mode=args.mode, min_weight_size=1024))
        sp, lut = st.params, st.lut
        print(f"{args.mode} weights: {sum(st.stats.values())/2**20:.2f} MiB")

    toks = data.batch_at(0)["tokens"]
    b, t0 = toks.shape
    caches = LM.init_caches(cfg, b, t0 + args.max_new, dtype=jnp.float32)
    prefill, decode = make_serve_fns(cfg)   # jitted + cached per config

    t = time.perf_counter()
    logits, caches = prefill(sp, lut, {"tokens": toks}, caches)
    jax.block_until_ready(logits)
    print(f"prefill: {1e3*(time.perf_counter()-t):.1f} ms")

    tok = jnp.argmax(logits, -1)[:, None].astype(toks.dtype)
    outs = [tok]
    t = time.perf_counter()
    for i in range(args.max_new - 1):
        logits, caches = decode(sp, lut, tok, caches, t0 + i)
        tok = jnp.argmax(logits, -1)[:, None].astype(toks.dtype)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t
    print(f"decode: {args.max_new-1} steps in {1e3*dt:.1f} ms "
          f"({b*(args.max_new-1)/dt:.1f} tok/s)")
    print("sample:", np.concatenate([np.asarray(o) for o in outs], 1)[0].tolist())


if __name__ == "__main__":
    main()
