"""ServeContext — one bundle for the serving invariants.

``make_serve_fns``/``generate`` historically threaded ``lut`` and ``mesh``
as loose keyword arguments through several private layers (and every new
serving entry point had to re-plumb them).  ``ServeContext`` carries the
full set — config, mesh, decode LUT, verify mode — as one object that the
engine, the continuous-batching scheduler, and the resilience wrapper all
share.  The loose ``lut=``/``mesh=`` kwargs still work but are deprecated
(they warn; see ``engine.generate``).

Only ``cfg`` and ``mesh`` participate in jit cache keys (both hashable);
``lut`` is an ordinary traced array and ``verify`` is host-side policy, so
the context itself is compared by identity (``eq=False``) — two contexts
over the same artifact are interchangeable, not equal.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional


@dataclasses.dataclass(frozen=True, eq=False)
class ServeContext:
    """Everything a serving call needs beyond (params, tokens).

    cfg:    the model config (hashable; jit static key).
    mesh:   concrete jax Mesh for sharded serving, or None (static key).
    lut:    the model-wide dictionary LUT for compressed decode, or None.
    verify: integrity-gate level — 'off' | 'fast' | 'full' (host policy,
            consumed by ResilientEngine / launch drivers, not by jit).
    residency: a ``serve.residency.ResidencyManager`` for tiered expert
            residency (host-RAM backing store + HBM expert cache), or None
            for fully-HBM-resident serving.  Host-side policy — every
            serving entry point that sees it routes steps through the
            manager's fetch/replay protocol; ``with_cfg`` preserves it, so
            degradation-ladder rungs share one cache.
    """
    cfg: Any
    mesh: Any = None
    lut: Any = None
    verify: str = "off"
    residency: Any = None

    @classmethod
    def from_state(cls, cfg, state, *, mesh=None,
                   verify: Optional[str] = None) -> "ServeContext":
        """Build from an ``engine.ServeState`` (lut comes off the state)."""
        return cls(cfg=cfg, mesh=mesh, lut=state.lut,
                   verify=verify if verify is not None else "off")

    def with_cfg(self, cfg) -> "ServeContext":
        """Same artifact, different (e.g. ladder-rung-suffixed) config."""
        return dataclasses.replace(self, cfg=cfg)
