"""Tiered expert residency — host-RAM backing store + HBM expert cache.

The paper's premise is a 4–8 GB unified-memory edge budget, but until now
every compressed expert plane had to be fully HBM-resident — Kimi-K2-class
configs (1T params) don't fit even compressed on small device counts.
This module decouples model size from HBM (QMoE's offload framing,
MobileMoE's router-driven on-device prefetch):

  * **Backing tier** — the compressed expert planes (codes / literals /
    nlit / scale / zero for w_gate / w_up / w_down) live pinned in host
    RAM as numpy arrays, integrity-checked against the pack-time manifest
    at construction and re-CRC'd per expert slice on every fetch
    (``core/integrity.py`` — a corrupt plane raises ``IntegrityError``
    naming (layer, expert, plane) *before* it reaches the device).
  * **HBM cache** — a fixed-capacity per-layer cache of hot experts,
    stored as C-slot stacked ``PackedLinear`` planes that feed the same
    grouped fused decode→dequant→matmul megakernel as the fully-resident
    path (``MATERIALIZE_COUNTS['packed_stacked']`` stays 0: a miss falls
    back to a synchronous fetch, never to materializing dense weights).
    Slots are LRU-evicted and generation-stamped; per-layer
    ``slot_of_expert`` / ``expert_of_slot`` maps travel *inside* the
    served param tree, so map changes are traced-value changes — never
    retraces.
  * **Bitwise parity** — ``models.layers.apply_moe`` gathers routed
    activations into slot order, runs the kernel over the C-slot stacks,
    and scatters outputs back to expert order with out-of-bounds→zero
    fills.  Resident experts see exactly the bytes and activations the
    fully-resident stack would give them; absent experts contribute only
    zero rows multiplied by their all-zero gate rows.  The manager
    guarantees every *routed* expert is resident before a step's outputs
    are used, via the fetch/replay protocol below — so outputs stay
    bitwise-equal to the fully-resident path at any capacity ≥ 1
    (asserted at capacities {all, half, 1} in tests/test_residency.py).

**Fetch/replay protocol** (``ResidencyManager.run``): launch the jitted
step against the current cache, read back the per-layer routing it
reports (``LM.forward(..., return_routing=True)``), and check it against
the slot table.  If every routed expert was resident, the outputs are
exact — commit (LRU touch, trim transient over-allocation, issue next
prefetches) and return.  Otherwise routing is only *trusted* up to the
first layer with a miss (deeper layers saw wrong inputs): fetch that
prefix's missing experts synchronously (the stall the benchmark measures)
and replay the same pure step — the trusted prefix grows by at least one
layer per pass, so the loop converges in ≤ n_layers passes.  A single
step's working set may transiently exceed the retained capacity (e.g.
capacity 1 with several routed experts): the cache *grows* extra slots
for the step and trims back to capacity at commit.

**Prefetch** (the routing-aware part): at commit, layer *l*'s observed
routing predicts layer *l+1*'s hot set one layer ahead — during decode
that is the previous token's routing, under the scheduler the previous
tick's.  A background worker slices + verifies + ``jax.device_put``s the
predicted experts while the host is between steps; ``run`` joins and
installs them (generation-stamped, source='prefetch') before the next
launch.  First use of a prefetched slot counts ``prefetch_hit``.

Observability: every event ticks ``RESIDENCY_COUNTS`` (hit / miss /
prefetch_hit / prefetch_issued / prefetch_installed / evict / sync_fetch
/ bytes_fetched / replay), mirrored per-manager with stall seconds;
``scheduler.Engine.health()`` and ``ResilientEngine.health()`` surface a
snapshot and ``benchmarks/residency.py`` lands the rates in
``BENCH_residency.json``.  Fetch faults (``FaultInjector.fetch_fault``
patches the module-level ``_transfer`` seam) raise ``JaxRuntimeError``
and walk the degradation ladder like any device fault — a miss-storm
under a persistent fault surfaces as refused requests, never a hang.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import queue
import threading
import time
import warnings
import zlib
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Set

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.compressed import PackedLinear
from repro.core.integrity import IntegrityError, IntegrityReport
from repro.models import lm as LM
from repro.serve import engine as _engine

# Residency probe: event -> count, reset by the autouse conftest fixture
# and by scheduler.Engine.reset_stats().  'hit': a routed expert was
# already cached; 'prefetch_hit': the hit's slot was installed by the
# prefetcher and this is its first use; 'miss'/'sync_fetch': a routed
# expert had to be fetched synchronously (stall); 'prefetch_issued'/
# 'prefetch_installed': predictions queued / landed in a slot; 'evict':
# an occupied slot was reassigned or trimmed; 'bytes_fetched': compressed
# bytes moved host->device; 'replay': extra fetch-and-replay passes.
RESIDENCY_COUNTS = collections.Counter()

_PLANES = ("codes", "literals", "nlit", "scale", "zero")
_EXPERT_KEYS = ("w_gate", "w_up", "w_down")


class ResidencyError(RuntimeError):
    """Residency-protocol failure (bad wiring, non-convergent replay)."""


def _transfer(arrays):
    """Host→device copy of one expert's planes ({(key, plane): np array}).

    The one seam every fetch and prefetch crosses — module-level so
    ``FaultInjector.fetch_fault`` can patch it to fail (raising
    ``jax.errors.JaxRuntimeError``, which walks the degradation ladder)
    or delay (modelling a saturated host↔device link).
    """
    return jax.device_put(arrays)


@jax.jit
def _slot_set(plane, l, s, val):
    """Write one expert's plane into cache slot (l, s) — (l, s) are traced
    scalars, so installs never retrace."""
    return plane.at[l, s].set(val)


@jax.jit
def _gather_slots(plane, idx):
    """Per-layer slot shuffle: plane (L, C, ...) gathered to (L, C', ...)
    by idx (L, C') — the trim-to-capacity compaction."""
    return plane[jnp.arange(plane.shape[0])[:, None], idx]


@dataclasses.dataclass
class _SlotRec:
    """Host-side record of one HBM cache slot."""
    expert: int = -1          # -1 = vacant
    last_used: int = 0        # LRU tick (monotonic per manager)
    gen: int = 0              # install generation stamp
    source: str = ""          # 'demand' | 'prefetch'
    fresh: bool = False       # installed but not yet served from


class ResidencyManager:
    """Owns the expert cache slots and the host backing store.

    state: an ``engine.ServeState`` (params + manifest), or any object
    with ``params``/``manifest`` attributes.  capacity: retained experts
    per layer (defaults to all — fully resident, but through the cache
    machinery); cache_bytes sizes capacity from an HBM byte budget
    instead.  prefetch=False disables the background worker (demand
    fetches only).  verify=False skips the construction-time manifest
    check (per-fetch slice CRCs still run).
    """

    def __init__(self, state, cfg, *, capacity: Optional[int] = None,
                 cache_bytes: Optional[int] = None, prefetch: bool = True,
                 verify: bool = True):
        params = getattr(state, "params", state)
        manifest = getattr(state, "manifest", None)
        if getattr(cfg, "moe_expert_scan", False):
            raise ResidencyError("tiered residency and moe_expert_scan are "
                                 "mutually exclusive (both own expert-"
                                 "granular memory)")
        if getattr(cfg, "moe_local_dispatch", False):
            raise ResidencyError("tiered residency requires global MoE "
                                 "dispatch (moe_local_dispatch=False)")
        try:
            experts = params["blocks"]["moe"]["experts"]
        except (KeyError, TypeError):
            raise ResidencyError("params carry no blocks.moe.experts stack "
                                 "— tiered residency needs an MoE family "
                                 "compressed model")
        for k in _EXPERT_KEYS:
            w = experts.get(k)
            if not (isinstance(w, PackedLinear) and w.codes.ndim == 4
                    and w.tile_n > 0):
                raise ResidencyError(
                    f"expert stack {k!r} is not a tile-major stacked "
                    f"PackedLinear — tiered residency caches compressed "
                    f"planes only (got {type(w).__name__})")
        self.cfg = cfg
        self._source_params = params
        self.n_layers, self.n_experts = (int(d) for d in
                                         experts["w_gate"].codes.shape[:2])

        # Backing tier: pinned host copies of every expert plane.
        self._host: Dict[str, Dict[str, np.ndarray]] = {
            k: {pl: np.array(jax.device_get(getattr(experts[k], pl)),
                             order="C")      # owned, writable host copy
                for pl in _PLANES}
            for k in _EXPERT_KEYS}
        self.bytes_per_expert = sum(
            self._host[k][pl][0, 0].nbytes
            for k in _EXPERT_KEYS for pl in _PLANES)
        if verify and manifest is not None:
            self._verify_backing(params, manifest)
        # Per-(layer, expert, plane) slice digests: every later fetch is
        # re-hashed against these, so backing-store rot is caught at fetch
        # time, named, and never served.
        self._slice_crc = {
            (l, e, k, pl): zlib.crc32(np.ascontiguousarray(
                self._host[k][pl][l, e]).reshape(-1).view(np.uint8))
            & 0xFFFFFFFF
            for k in _EXPERT_KEYS for pl in _PLANES
            for l in range(self.n_layers) for e in range(self.n_experts)}

        granted_bytes = None
        if capacity is None and cache_bytes is not None:
            granted_bytes = int(cache_bytes)
            capacity = int(cache_bytes //
                           (self.n_layers * self.bytes_per_expert))
        elif capacity is not None:
            granted_bytes = int(capacity) * self.n_layers \
                * self.bytes_per_expert
        self.capacity = (self.n_experts if capacity is None
                         else max(1, min(int(capacity), self.n_experts)))
        # The cache floor is one expert per layer — a smaller grant is
        # clamped UP, which overshoots the caller's byte budget.  Never
        # hide that: warn here, record it for snapshot()/health(), and let
        # DeviceBudget.summary(expert_cache_used=...) print it.
        floor_bytes = self.n_layers * self.bytes_per_expert
        self.overshoot_bytes = 0
        if granted_bytes is not None and granted_bytes < floor_bytes:
            self.overshoot_bytes = floor_bytes - max(granted_bytes, 0)
            warnings.warn(
                f"expert-cache budget {granted_bytes / 2**20:.2f} MiB grants "
                f"0 experts/layer; clamping to capacity 1 overshoots the "
                f"budget by {self.overshoot_bytes / 2**20:.2f} MiB "
                f"({self.n_layers} layers x "
                f"{self.bytes_per_expert / 2**20:.2f} MiB/expert)",
                RuntimeWarning, stacklevel=2)
        self.c_alloc = self.capacity
        self.boot_capacity = self.capacity

        # HBM tier: zero-initialised C-slot cache stacks, same container
        # metadata as the source so the grouped-kernel gate stays open.
        self._stacks: Dict[str, PackedLinear] = {}
        for k in _EXPERT_KEYS:
            src = experts[k]
            zp = {pl: jnp.zeros(
                (self.n_layers, self.c_alloc) + self._host[k][pl].shape[2:],
                getattr(src, pl).dtype) for pl in _PLANES}
            self._stacks[k] = PackedLinear(
                zp["codes"], zp["literals"], zp["nlit"], zp["scale"],
                zp["zero"], shape=src.shape, seq_len=src.seq_len,
                row_parallel=src.row_parallel, tile_n=src.tile_n,
                tile_k=src.tile_k)

        # Served tree: the caller's params with the expert stacks swapped
        # for the cache stacks and the residency maps riding alongside
        # (layer-sliced by the block scan).  Non-expert leaves are shared
        # by reference.
        blocks = dict(params["blocks"])
        moe = dict(blocks["moe"])
        moe["experts"] = self._stacks
        self._res_maps: Dict[str, jax.Array] = {}
        moe["residency"] = self._res_maps
        blocks["moe"] = moe
        self._dp = {**params, "blocks": blocks}

        self._slots: List[List[_SlotRec]] = [
            [_SlotRec() for _ in range(self.c_alloc)]
            for _ in range(self.n_layers)]
        self._where: List[Dict[int, int]] = [
            {} for _ in range(self.n_layers)]
        self._maps_dirty = True
        self._ticks = 0
        self._gen = 0
        self._last_needed: Dict[int, Set[int]] = {}

        self.prefetch_enabled = bool(prefetch)
        self._prefetch_boot = bool(prefetch)
        self._worker: Optional[threading.Thread] = None
        self._queue: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._ready: list = []      # [(l, e, device arrays)]
        self._errors: list = []     # [(l, e, repr(exc))]
        self._inflight: Set[tuple] = set()
        self.reset_stats()

    # -- stats ----------------------------------------------------------
    def reset_stats(self) -> None:
        self.stats = {k: 0 for k in
                      ("hit", "miss", "prefetch_hit", "prefetch_issued",
                       "prefetch_installed", "prefetch_error", "evict",
                       "fetch", "sync_fetch", "bytes_fetched", "replay",
                       "steps")}
        self.stall_s = 0.0

    def _count(self, key: str, n: int = 1) -> None:
        RESIDENCY_COUNTS[key] += n
        self.stats[key] = self.stats.get(key, 0) + n

    def snapshot(self) -> dict:
        """Health/benchmark view: counters + sizing + derived rates."""
        s = dict(self.stats)
        looks = s["hit"] + s["prefetch_hit"] + s["miss"]
        s.update(
            capacity=self.capacity, slots_allocated=self.c_alloc,
            layers=self.n_layers, experts=self.n_experts,
            bytes_per_expert=self.bytes_per_expert,
            overshoot_bytes=self.overshoot_bytes,
            prefetch_enabled=self.prefetch_enabled,
            stall_s=round(self.stall_s, 6),
            stall_per_miss_ms=round(1e3 * self.stall_s / max(s["miss"], 1),
                                    4),
            hit_rate=(round((s["hit"] + s["prefetch_hit"]) / looks, 4)
                      if looks else None),
            prefetch_hit_rate=(round(s["prefetch_hit"] / looks, 4)
                               if looks else None),
            generation=self._gen)
        return s

    def resident(self, layer: int) -> Dict[int, int]:
        """{expert: slot} currently cached at ``layer`` (tests/debug)."""
        return dict(self._where[layer])

    def slot_table(self, layer: int) -> list:
        """Generation-stamped slot table at ``layer`` (tests/debug)."""
        return [dataclasses.replace(r) for r in self._slots[layer]]

    # -- integrity ------------------------------------------------------
    def _verify_backing(self, params, manifest) -> None:
        """Construction gate: the expert planes about to back the cache
        must re-hash to their pack-time manifest digests."""
        from repro.core import integrity as _integrity
        t0 = time.perf_counter()
        corrupt, checked, hashed = [], 0, 0
        for name, arr in _integrity._iter_plane_leaves(params):
            if "'experts'" not in name:
                continue
            entry = manifest["leaves"].get(name)
            if entry is None:
                corrupt.append((name, "-", "leaf absent from manifest"))
                continue
            hashed += _integrity._check_plane(
                name, _integrity._plane_tag(name), arr, entry, "full",
                corrupt)
            checked += 1
        report = IntegrityReport("residency-init", not corrupt, corrupt,
                                 checked, hashed,
                                 time.perf_counter() - t0)
        if not report.ok:
            raise IntegrityError(report)

    def _verify_slice(self, l: int, e: int, arrs) -> None:
        t0 = time.perf_counter()
        corrupt, hashed = [], 0
        for (k, pl), a in arrs.items():
            u8 = np.ascontiguousarray(a).reshape(-1).view(np.uint8)
            hashed += u8.size
            got = zlib.crc32(u8) & 0xFFFFFFFF
            want = self._slice_crc[(l, e, k, pl)]
            if got != want:
                corrupt.append(
                    (f"blocks.moe.experts.{k}[layer {l}, expert {e}]", pl,
                     f"crc32 {got:#010x} != recorded {want:#010x} at "
                     f"fetch time"))
        if corrupt:
            raise IntegrityError(IntegrityReport(
                "fetch", False, corrupt, len(arrs), hashed,
                time.perf_counter() - t0))

    # -- device tree ----------------------------------------------------
    def check_params(self, params) -> None:
        """Tiered closures serve from the manager's spliced tree; the
        caller-passed params must be the tree this manager was built on
        (anything else would silently serve different weights)."""
        if params is not None and params is not self._source_params:
            raise ResidencyError(
                "params passed to a tiered serve fn are not the tree this "
                "ResidencyManager was built from — build the manager from "
                "the same ServeState you serve")

    def device_params(self):
        """The served param tree (cache stacks + current residency maps)."""
        if self._maps_dirty:
            soe = np.full((self.n_layers, self.n_experts), self.c_alloc,
                          np.int32)
            eos = np.full((self.n_layers, self.c_alloc), self.n_experts,
                          np.int32)
            for l, recs in enumerate(self._slots):
                for s, r in enumerate(recs):
                    if r.expert >= 0:
                        soe[l, r.expert] = s
                        eos[l, s] = r.expert
            self._res_maps["slot_of_expert"] = jnp.asarray(soe)
            self._res_maps["expert_of_slot"] = jnp.asarray(eos)
            self._maps_dirty = False
        return self._dp

    # -- slot mechanics -------------------------------------------------
    def _tick(self) -> int:
        self._ticks += 1
        return self._ticks

    def _find(self, l: int, e: int) -> Optional[int]:
        return self._where[l].get(int(e))

    def _touch(self, rec: _SlotRec) -> None:
        rec.last_used = self._tick()

    def _fetch(self, l: int, e: int):
        """Slice one expert off the backing store, verify, move to device."""
        arrs = {(k, pl): np.ascontiguousarray(self._host[k][pl][l, e])
                for k in _EXPERT_KEYS for pl in _PLANES}
        self._verify_slice(l, e, arrs)
        dev = _transfer(arrs)
        nbytes = sum(a.nbytes for a in arrs.values())
        self._count("fetch")
        self._count("bytes_fetched", nbytes)
        return dev

    def _install(self, l: int, e: int, dev, source: str,
                 protected: Set[int]) -> int:
        """Place fetched planes into a slot at layer ``l``: vacant first,
        else evict the LRU slot whose expert is not ``protected``."""
        recs = self._slots[l]
        slot = next((i for i, r in enumerate(recs) if r.expert < 0), None)
        if slot is None:
            cands = [(r.last_used, i) for i, r in enumerate(recs)
                     if r.expert not in protected]
            if not cands:
                self._grow(1)
                recs = self._slots[l]
                slot = len(recs) - 1
            else:
                slot = min(cands)[1]
                self._count("evict")
                self._where[l].pop(recs[slot].expert, None)
        li, si = jnp.int32(l), jnp.int32(slot)
        for k in _EXPERT_KEYS:
            stack = self._stacks[k]
            for pl in _PLANES:
                setattr(stack, pl,
                        _slot_set(getattr(stack, pl), li, si, dev[(k, pl)]))
        self._gen += 1
        recs[slot] = _SlotRec(expert=int(e), last_used=self._tick(),
                              gen=self._gen, source=source,
                              fresh=(source == "prefetch"))
        self._where[l][int(e)] = slot
        self._maps_dirty = True
        return slot

    def _grow(self, extra: int) -> None:
        """Transiently widen the cache (a step's working set may exceed
        the retained capacity); commit trims back via :meth:`_trim`."""
        for k in _EXPERT_KEYS:
            stack = self._stacks[k]
            for pl in _PLANES:
                plane = getattr(stack, pl)
                pad = jnp.zeros(
                    (self.n_layers, extra) + tuple(plane.shape[2:]),
                    plane.dtype)
                setattr(stack, pl, jnp.concatenate([plane, pad], axis=1))
        for recs in self._slots:
            recs.extend(_SlotRec() for _ in range(extra))
        self.c_alloc += extra
        self._maps_dirty = True

    def _trim(self) -> None:
        """Compact back to ``capacity`` slots, keeping the most recently
        used experts per layer (the LRU tail is evicted)."""
        if self.c_alloc <= self.capacity:
            return
        keep = np.zeros((self.n_layers, self.capacity), np.int64)
        new_slots: List[List[_SlotRec]] = []
        for l, recs in enumerate(self._slots):
            order = sorted(range(len(recs)),
                           key=lambda i: (recs[i].expert < 0,
                                          -recs[i].last_used, i))
            kept, dropped = order[:self.capacity], order[self.capacity:]
            for i in dropped:
                if recs[i].expert >= 0:
                    self._count("evict")
            keep[l] = kept
            new_slots.append([recs[i] for i in kept])
        idx = jnp.asarray(keep)
        for k in _EXPERT_KEYS:
            stack = self._stacks[k]
            for pl in _PLANES:
                setattr(stack, pl, _gather_slots(getattr(stack, pl), idx))
        self._slots = new_slots
        self._where = [{r.expert: s for s, r in enumerate(recs)
                        if r.expert >= 0} for recs in new_slots]
        self.c_alloc = self.capacity
        self._maps_dirty = True

    # -- runtime capacity (memory-pressure governor) --------------------
    def set_capacity(self, capacity: int) -> None:
        """Re-size the retained per-layer cache at runtime.

        Shrinking compacts the C-slot stacks to the new capacity (MRU
        experts survive, the LRU tail is evicted); growing pads vacant
        slots eagerly so regrown room is used by installs instead of
        evictions.  Either direction changes the stack shapes, so the
        next jitted step **re-traces** — callers (the governor) must
        fence this between scheduler steps and amortize it with
        hysteresis, never per-step.  Parity is unaffected: the
        fetch/replay protocol re-fetches whatever a later step routes to,
        so mid-stream shrink-to-1-then-regrow stays bitwise-equal
        (tests/test_residency.py).  Clamped to [1, n_experts]; a clamp-up
        from a sub-floor request records ``overshoot_bytes``."""
        want = int(capacity)
        capacity = max(1, min(want, self.n_experts))
        floor_bytes = self.n_layers * self.bytes_per_expert
        self.overshoot_bytes = floor_bytes if want < 1 else 0
        if capacity == self.capacity:
            return
        self.join_prefetches()       # no installs racing the re-shape
        self.capacity = capacity
        if self.c_alloc > capacity:
            self._trim()
        elif self.c_alloc < capacity:
            self._grow(capacity - self.c_alloc)
        self._maps_dirty = True

    def pause_prefetch(self) -> None:
        """Stop issuing predictions (reclaim rung 1): in-flight fetches
        drain at the next ``join_prefetches`` and still install — pausing
        stops new host→HBM traffic, it never corrupts the protocol."""
        self.prefetch_enabled = False

    def resume_prefetch(self) -> None:
        """Re-enable prediction issue (regrow), back to the boot setting."""
        self.prefetch_enabled = self._prefetch_boot

    # -- prefetch -------------------------------------------------------
    def _start_worker(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._work, daemon=True,
                                            name="residency-prefetch")
            self._worker.start()

    def _work(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                self._queue.task_done()
                return
            l, e = item
            try:
                arrs = {(k, pl):
                        np.ascontiguousarray(self._host[k][pl][l, e])
                        for k in _EXPERT_KEYS for pl in _PLANES}
                self._verify_slice(l, e, arrs)
                dev = _transfer(arrs)
                with self._lock:
                    self._ready.append((l, e, dev,
                                        sum(a.nbytes
                                            for a in arrs.values())))
            except Exception as exc:   # swallowed: a failed prefetch just
                with self._lock:       # becomes a later (loud) demand miss
                    self._errors.append((l, e, repr(exc)))
            finally:
                self._queue.task_done()

    def close(self) -> None:
        """Stop and join the prefetch worker.  Idempotent; called by
        ``scheduler.Engine.close()`` / ``ResilientEngine.close()`` so
        serving teardown leaves no live ``residency-prefetch`` thread
        (asserted in tests)."""
        if self._worker is not None and self._worker.is_alive():
            self._queue.put(None)
            self._queue.join()
            self._worker.join(timeout=5.0)
        self._worker = None

    def join_prefetches(self) -> None:
        """Wait out in-flight prefetches and install what landed — called
        at the top of every :meth:`run`, so installs are deterministic
        with respect to the step sequence (the async overlap happens
        *between* steps)."""
        if self._worker is None:
            return
        self._queue.join()
        with self._lock:
            ready, self._ready = self._ready, []
            errors, self._errors = self._errors, []
        for l, e, _ in errors:
            self._count("prefetch_error")
            self._inflight.discard((l, e))
        for l, e, dev, nbytes in ready:
            self._inflight.discard((l, e))
            if self._find(l, e) is not None:
                continue               # raced with a demand fetch
            self._count("fetch")
            self._count("bytes_fetched", nbytes)
            self._install(l, e, dev, "prefetch",
                          protected=self._last_needed.get(l, set()))
            self._count("prefetch_installed")

    def _issue_prefetches(self, needed: Sequence[Set[int]]) -> None:
        """Routing-aware prediction: layer l-1's observed routing
        prefetches layer l one layer ahead (decode: the previous token's
        logits; scheduler: the previous tick's routing), plus temporal
        locality on the layer's own hot set (already resident → no-op)."""
        for l in range(self.n_layers):
            pred: Set[int] = set()
            if l < len(needed):
                pred |= needed[l]
            if 0 < l and l - 1 < len(needed):
                pred |= needed[l - 1]
            for e in sorted(pred):
                if self._find(l, e) is None \
                        and (l, e) not in self._inflight:
                    self._inflight.add((l, e))
                    self._count("prefetch_issued")
                    self._start_worker()
                    self._queue.put((l, e))

    # -- the protocol ---------------------------------------------------
    def _ensure(self, needed: Sequence[Set[int]],
                counted: Optional[set] = None) -> None:
        """Account hits and synchronously fetch misses for ``needed``
        (a per-layer sequence of expert-id sets); ``counted`` dedupes
        accounting across replay passes of one step."""
        counted = set() if counted is None else counted
        worst = max((len(exps) for exps in needed), default=0)
        if worst > self.c_alloc:
            self._grow(worst - self.c_alloc)
        for l, exps in enumerate(needed):
            for e in sorted(int(x) for x in exps):
                slot = self._find(l, e)
                if slot is not None:
                    rec = self._slots[l][slot]
                    if (l, e) not in counted:
                        counted.add((l, e))
                        if rec.fresh and rec.source == "prefetch":
                            self._count("prefetch_hit")
                        else:
                            self._count("hit")
                    rec.fresh = False
                    self._touch(rec)
                else:
                    if (l, e) not in counted:
                        counted.add((l, e))
                        self._count("miss")
                    self._count("sync_fetch")
                    t0 = time.perf_counter()
                    dev = self._fetch(l, e)
                    s = self._install(l, e, dev, "demand", protected=exps)
                    self.stall_s += time.perf_counter() - t0
                    rec = self._slots[l][s]
                    rec.fresh = False
                    self._touch(rec)

    def _commit(self, needed: Sequence[Set[int]]) -> None:
        self.stats["steps"] += 1
        self._trim()
        self._last_needed = {l: set(exps) for l, exps in enumerate(needed)}
        if self.prefetch_enabled:
            self._issue_prefetches(needed)

    def _needed(self, routing: np.ndarray, active) -> List[Set[int]]:
        """Per-layer routed-expert sets from a (L, n_tok, k) routing
        tensor, keeping only rows of ``active`` slots when given."""
        r = np.asarray(routing)
        lm = r.shape[0]
        r = r.reshape(lm, -1, r.shape[-1])
        if active is not None:
            act = np.asarray(active, bool).reshape(-1)
            if act.size and r.shape[1] % act.size == 0:
                per = r.shape[1] // act.size
                r = r.reshape(lm, act.size, per, r.shape[-1])[:, act]
                r = r.reshape(lm, -1, routing.shape[-1])
            if not act.any():
                return [set() for _ in range(lm)]
        return [set(np.unique(r[l]).tolist()) if r[l].size else set()
                for l in range(lm)]

    def step(self, needed: Sequence) -> None:
        """Trace-driven tick: make ``needed`` (per-layer expert-id
        iterables) resident, commit, prefetch — the replayable form of
        :meth:`run` used by tests and trace benchmarks."""
        self.join_prefetches()
        needed = [set(int(e) for e in exps) for exps in needed]
        self._ensure(needed)
        self._commit(needed)

    def run(self, launch, *, active=None):
        """Execute one jitted serve step under the fetch/replay protocol.

        ``launch(device_params) -> (out, routing)`` must be pure in its
        inputs (replayed outputs are discarded — jitted serve steps
        qualify; callers must not commit side state from a replayed
        pass).  ``active``: optional (B,) bool mask — only active slots'
        routing drives fetches (inactive scheduler slots compute garbage
        that is masked out of storage).  Returns ``out`` from the first
        fully-resident pass; raises on non-convergence (> n_layers
        replays means routing never stabilised, which the trusted-prefix
        argument rules out for pure launches).
        """
        self.join_prefetches()
        counted: set = set()
        for _ in range(self.n_layers + 1):
            out, routing = launch(self.device_params())
            needed = self._needed(np.asarray(routing), active)
            missing = [(l, e) for l, exps in enumerate(needed)
                       for e in exps if self._find(l, int(e)) is None]
            if not missing:
                self._ensure(needed, counted)
                self._commit(needed)
                return out
            # routing is only trustworthy up to the first missing layer —
            # deeper layers saw zero rows where this layer's experts
            # should have fired.  Fetch the trusted prefix and replay.
            first = min(l for l, _ in missing)
            self._count("replay")
            self._ensure(needed[:first + 1], counted)
        raise ResidencyError(
            f"fetch/replay did not converge after {self.n_layers + 1} "
            f"passes — launch is not pure in the served params")


# ---------------------------------------------------------------------------
# Tiered serve fns (engine-compatible closures over the manager).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _routed_step_fns(cfg):
    """Jitted (prefill, decode_step) that also return per-layer routing;
    cached per cfg so degradation-ladder rungs re-trace under their own
    suffixed configs, exactly like ``engine._jitted_serve_fns``."""
    prefill, decode_step = _engine._raw_serve_fns(cfg, routing=True)
    return jax.jit(prefill), jax.jit(decode_step)


def make_tiered_serve_fns(ctx):
    """(prefill, decode_step) with the standard engine signatures, each
    step routed through ``ctx.residency``'s fetch/replay protocol.  The
    closures serve from the manager's spliced tree (C-slot cache stacks +
    residency maps); the caller-passed params must be the tree the
    manager was built from.  Always jitted inside; mesh-less only."""
    mgr = ctx.residency
    if mgr is None:
        raise ResidencyError("ctx.residency is None — use "
                             "engine.make_serve_fns for resident serving")
    if ctx.mesh is not None:
        raise ResidencyError("tiered residency is single-device (the HBM "
                             "cache is per-process) — mesh must be None")
    jp, jd = _routed_step_fns(ctx.cfg)

    def prefill(params, lut, batch, caches):
        mgr.check_params(params)

        def launch(dp):
            logits, new_caches, eids = jp(dp, lut, batch, caches)
            return (logits, new_caches), eids

        return mgr.run(launch)

    def decode_step(params, lut, token, caches, pos):
        mgr.check_params(params)

        def launch(dp):
            logits, new_caches, eids = jd(dp, lut, token, caches, pos)
            return (logits, new_caches), eids

        return mgr.run(launch)

    return prefill, decode_step


def tiered_generate(params, cfg, tokens, *, ctx, max_new: int = 16,
                    max_len: Optional[int] = None, temperature: float = 0.0,
                    key=None, embeds=None):
    """One-shot generation under tiered residency — the host-stepped
    mirror of ``engine.generate``'s scan loop (same prefill shape, same
    ``sample_tokens`` rule, same per-step key splits), bitwise-equal to
    it at any cache capacity because every committed step saw all its
    routed experts resident (see module docstring / apply_moe)."""
    mgr = ctx.residency
    lut = ctx.lut
    if max_new <= 0:
        return tokens
    b, t0 = tokens.shape
    extra = embeds.shape[1] if embeds is not None else 0
    max_len = max_len or (t0 + extra + max_new)
    caches = LM.init_caches(cfg, b, max_len)
    use_ctx = ctx if ctx.cfg is cfg else ctx.with_cfg(cfg)
    prefill, decode_step = make_tiered_serve_fns(use_ctx)
    logits, caches = prefill(params, lut,
                             {"tokens": tokens, "embeds": embeds}, caches)
    tok0 = _engine.sample_tokens(logits, 0.0)[:, None].astype(tokens.dtype)
    if max_new <= 1:
        return jnp.concatenate([tokens, tok0], axis=1)
    temperature = float(temperature)
    sample = temperature > 0 and key is not None
    outs = [tok0]
    tok, pos = tok0, t0 + extra
    for _ in range(max_new - 1):
        logits, caches = decode_step(params, lut, tok, caches,
                                     jnp.asarray(pos, jnp.int32))
        if sample:
            key, sub = jax.random.split(key)
            nxt = _engine.sample_tokens(
                logits, temperature, sub)[:, None].astype(tok.dtype)
        else:
            nxt = _engine.sample_tokens(logits, 0.0)[:, None].astype(
                tok.dtype)
        outs.append(nxt)
        tok, pos = nxt, pos + 1
    return jnp.concatenate([tokens] + outs, axis=1)


@partial(jax.jit, static_argnums=(0, 1, 2))
def _tiered_generate_step(cfg, mesh, page_size: int, params, lut, pages,
                          page_table, tok, pos, active, temp, keys):
    """The scheduler's ``_generate_step`` with routing threaded out —
    identical paged view / sampling / write-token body, so per-request
    outputs stay bitwise-equal to the resident scheduler (and transitively
    to one-shot ``generate``).  Returns (pages, next tokens, routing)."""
    from repro.serve.kv_cache import paged_view, write_token
    _engine.TRACE_COUNTS["generate_step"] += 1
    _, decode_step = _engine._raw_serve_fns(cfg, routing=True)
    with _engine._mesh_ctx(mesh):
        view = paged_view(cfg, pages, page_table)
        logits, new_view, routing = decode_step(params, lut, tok, view, pos)
        subs = jax.vmap(jax.random.fold_in)(keys, pos)
        nxt = _engine.sample_tokens(logits, temp, subs)
        pages = write_token(cfg, page_size, pages, new_view, page_table,
                            pos, active)
    return pages, nxt, routing
