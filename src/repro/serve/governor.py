"""Memory-pressure governor — runtime budget adaptation for serving.

The deployment regime is a 4–8 GB *unified-memory* edge device: the
model shares RAM with the OS and co-tenant apps, so the HBM budget
``core/policy.py::DeviceBudget`` split at boot is not a constant.
Jetsam-style pressure can reclaim hundreds of MiB mid-decode; a serving
engine that treats its boot split as permanent either OOM-crashes or
gets killed.  PR 8/9 made every serving resource *elastic in
principle* — bounded queue, preempt+resume, tiered expert residency,
an (as of this PR) shrinkable paged KV pool.  ``MemoryGovernor`` is the
robustness layer that drives them when the budget actually moves.

Reclaim ladder (budget fell; applied immediately at the next step fence,
where no jitted call is in flight):

  1. **Trim the expert cache** — pause residency prefetch and shrink
     ``ResidencyManager.capacity`` toward its floor of one expert per
     layer.  Capacity changes re-shape the slot stacks → the tiered
     decode step re-traces once.
  2. **Shrink the KV pool** — retire free pages (highest ids first so a
     contiguous tail can be physically sliced off the device arrays);
     if the free list cannot cover the shortfall, preempt the lowest-
     priority in-flight request through the PR 8 evict+requeue path
     (``Engine.preempt_lowest``) and retire its pages.  Victims resume
     bitwise-equal via re-prefill once pages exist again.  Floor: one
     slot's worth of pages, so a drain always converges.
  3. **Tighten admission** — cap ``max_queue`` at the number of slots
     the shrunken pool can still back; excess submissions shed through
     the existing bounded-queue machinery.
  4. **Refuse new work** — below ``min_viable`` (inelastic reserve +
     both floors) even the floors overshoot; new submissions complete
     as ``finished='pressure'`` instead of queuing behind an engine
     that cannot serve them.  In-flight and queued work still drains.

Regrow ladder (budget recovered) is the same plan applied in reverse —
admission loosens, pages restore, capacity regrows, prefetch resumes —
but gated by **hysteresis**: the surplus must exceed the applied budget
by ``hysteresis`` (or reach the boot budget outright) and hold for
``cooldown_steps`` consecutive steps.  The budget→plan mapping is
quantized to integers (capacity, usable pages, admission bound), so an
oscillating signal inside one hysteresis band produces *zero* plan
changes — capacity never thrashes and nothing re-traces per step; the
total number of re-traces is bounded by the number of band crossings
the trace actually sustains.

Accounting invariant (ROADMAP): under any pressure trace the engine's
*accounted* footprint (resident + activations + capacity·expert bytes +
usable pages) never exceeds the instantaneous budget by more than one
step's working set; physical release of a retired-page tail blocked by
live tenants completes as soon as those tenants retire.  Every affected
request still ends as a ``Completion`` (``finished`` ∈ {eos, max_new,
shed, deadline, refused, pressure}), and survivors stay bitwise-equal
to an unpressured run — pressure moves *where* KV lives and *when*
requests run, never *what* they compute.

Pressure sources, in precedence order each ``on_step``:

  * ``_os_pressure()`` — module seam, normally ``None``; patched by
    ``testing.faults.FaultInjector.memory_pressure`` to replay a seeded
    trace (step / spike / ramp / oscillate).
  * the ``poll`` callback handed to the constructor (an OS integration
    would read cgroup/jetsam watermarks here);
  * explicit ``set_budget`` calls (benchmarks, operators).
"""
from __future__ import annotations

import time
from typing import Callable, List, NamedTuple, Optional

from repro.core.policy import DeviceBudget
from repro.serve.resilience import FALLBACK_COUNTS


def _os_pressure() -> Optional[int]:
    """Pressure seam: current total budget in bytes, or None for 'no
    signal'.  ``FaultInjector.memory_pressure`` patches this to replay a
    seeded trace; a real deployment would poll jetsam / cgroup
    watermarks."""
    return None


class Plan(NamedTuple):
    """One integer-quantized resource split.  ``capacity`` is experts
    per layer in the residency cache (None = no tiered residency);
    ``pages`` is usable KV pages in circulation; ``max_queue`` the
    admission bound (None = engine boot value / unbounded); ``refusing``
    flips rung 4."""
    capacity: Optional[int]
    pages: int
    max_queue: Optional[int]
    refusing: bool


class MemoryGovernor:
    """Walks the reclaim/regrow ladder when the HBM budget moves.

    budget: the boot ``DeviceBudget``.  poll: optional zero-arg callable
    returning the current budget in bytes (or None).  hysteresis:
    fractional surplus required before regrowing.  cooldown_steps:
    consecutive steps the surplus must hold.  min_budget_bytes: operator
    floor — below ``max(min_viable, min_budget_bytes)`` the governor
    refuses new work instead of reclaiming further.

    Attach via ``Engine(..., governor=gov)``; the engine calls
    ``on_step`` at the top of every tick (the only fence where no jitted
    call is in flight, so re-shaping traced arrays is safe).
    """

    def __init__(self, budget: DeviceBudget, *,
                 poll: Optional[Callable[[], Optional[int]]] = None,
                 hysteresis: float = 0.1, cooldown_steps: int = 4,
                 min_budget_bytes: Optional[int] = None):
        self.budget = budget              # current (re-split) view
        self.boot_bytes = int(budget.budget_bytes)
        self.poll = poll
        self.hysteresis = float(hysteresis)
        self.cooldown_steps = int(cooldown_steps)
        self.min_budget_bytes = min_budget_bytes
        self.target_bytes = int(budget.budget_bytes)
        self.applied_bytes = int(budget.budget_bytes)
        self.refusing = False
        self.engine = None
        self.events: List[dict] = []      # bounded: last _MAX_EVENTS
        self.rung_latency: dict = {}      # rung -> last apply seconds
        self.plan_changes = 0
        self._grow_streak = 0

    _MAX_EVENTS = 256

    # -- wiring --------------------------------------------------------
    def attach(self, engine) -> None:
        """Called by ``Engine.__init__``; captures the boot envelope the
        regrow ladder restores toward."""
        self.engine = engine
        pool = engine.pool
        self._pages_per_slot = pool.pages_per_slot
        self._page_nbytes = pool.page_nbytes()
        self._boot_pages = pool.n_pages
        self._boot_kv_bytes = self._boot_pages * self._page_nbytes
        self._boot_max_queue = engine.max_queue
        mgr = getattr(engine.ctx, "residency", None)
        self._mgr = mgr
        if mgr is not None:
            self._boot_capacity = mgr.capacity
            self._unit = mgr.n_layers * mgr.bytes_per_expert
        else:
            self._boot_capacity = None
            self._unit = 0
        kv_floor = self._pages_per_slot * self._page_nbytes
        self.refuse_below = max(
            self.budget.min_viable(kv_floor_bytes=kv_floor,
                                   expert_floor_bytes=self._unit),
            self.min_budget_bytes or 0)
        self.applied_plan = self._plan(self.applied_bytes)

    def set_budget(self, budget_bytes: int) -> None:
        """Record a new total budget; applied at the next step fence."""
        self.target_bytes = max(0, int(budget_bytes))

    # -- plan ----------------------------------------------------------
    def _plan(self, budget_bytes: int) -> Plan:
        """Map a budget to an integer resource split (monotone in the
        budget, so any single move shrinks-or-grows every dimension the
        same way).  Experts absorb the deficit first — they are the
        cheapest to restore (a refetch from host RAM) — then KV pages,
        then admission, then refusal."""
        b = max(0, int(budget_bytes))
        avail = b - self.budget.resident_bytes - self.budget.act_bytes
        cap = self._boot_capacity
        exp_bytes = 0
        if self._unit > 0:
            cap = (avail - self._boot_kv_bytes) // self._unit
            cap = max(1, min(int(cap), self._boot_capacity))
            exp_bytes = cap * self._unit
        pages = self._boot_pages
        if self._page_nbytes > 0:
            pages = (avail - exp_bytes) // self._page_nbytes
            pages = max(self._pages_per_slot,
                        min(int(pages), self._boot_pages))
        slots_backed = pages // self._pages_per_slot
        max_queue = self._boot_max_queue
        if slots_backed < self.engine.pool.n_slots:
            bound = max(1, slots_backed)
            max_queue = (bound if max_queue is None
                         else min(max_queue, bound))
        return Plan(capacity=cap, pages=pages, max_queue=max_queue,
                    refusing=b < self.refuse_below)

    @staticmethod
    def _shrinks(new: Plan, old: Plan) -> bool:
        inf = float("inf")
        return ((new.capacity or 0) < (old.capacity or 0)
                or new.pages < old.pages
                or (inf if new.max_queue is None else new.max_queue)
                < (inf if old.max_queue is None else old.max_queue)
                or (new.refusing and not old.refusing))

    # -- the ladder ----------------------------------------------------
    def on_step(self, engine) -> None:
        """Step-fence hook: ingest the pressure signal, re-plan, and
        apply a reclaim immediately or a regrow behind hysteresis."""
        sig = _os_pressure()
        if sig is None and self.poll is not None:
            sig = self.poll()
        if sig is not None:
            self.set_budget(sig)
        target = self._plan(self.target_bytes)
        if target == self.applied_plan:
            self._grow_streak = 0
            self.applied_bytes = min(self.applied_bytes, self.target_bytes)
            return
        if self._shrinks(target, self.applied_plan):
            self._apply(target, regrow=False)
            return
        # regrow: demand a sustained, hysteresis-sized surplus (or full
        # recovery to the boot budget) so band-oscillation never thrashes
        floor = self.applied_bytes * (1.0 + self.hysteresis)
        if (self.target_bytes >= floor
                or self.target_bytes >= self.boot_bytes):
            self._grow_streak += 1
        else:
            self._grow_streak = 0
            return
        if self._grow_streak >= self.cooldown_steps:
            self._apply(target, regrow=True)
            self._grow_streak = 0

    def _apply(self, plan: Plan, *, regrow: bool) -> None:
        engine = self.engine
        old = self.applied_plan
        if regrow:
            FALLBACK_COUNTS["pressure_regrow"] += 1
        # rung 3/4 first on regrow, last on reclaim — but both are pure
        # host state, so ordering only matters for the elastic tiers:
        # reclaim trims experts before KV, regrow restores KV before
        # experts (experts are the cheapest to give and the last to get
        # back; KV directly gates in-flight progress).
        if plan.refusing != old.refusing:
            self.refusing = plan.refusing
        if plan.max_queue != old.max_queue:
            engine.max_queue = (plan.max_queue if plan.max_queue is not None
                                else self._boot_max_queue)
            if not regrow:
                FALLBACK_COUNTS["pressure_tighten"] += 1
                self._event("tighten", f"max_queue={plan.max_queue}", 0.0)
        tiers = ("kv", "experts") if regrow else ("experts", "kv")
        for tier in tiers:
            if tier == "experts":
                self._apply_experts(plan, old, regrow)
            else:
                self._apply_kv(plan, old, regrow)
        # prefetch rides the pressure state: paused under any trim,
        # resumed only at full recovery (mid-band prefetch would fight
        # the next reclaim for cache slots)
        if self._mgr is not None:
            if plan == self._plan(self.boot_bytes) \
                    and plan.capacity == self._boot_capacity:
                self._mgr.resume_prefetch()
            else:
                self._mgr.pause_prefetch()
        self.applied_plan = plan
        self.applied_bytes = self.target_bytes
        self.plan_changes += 1
        self.budget = self.budget.resplit(
            self.target_bytes, kv_bytes=plan.pages * self._page_nbytes)

    def _apply_experts(self, plan: Plan, old: Plan, regrow: bool) -> None:
        if self._mgr is None or plan.capacity == old.capacity:
            return
        t0 = time.perf_counter()
        if not regrow:
            self._mgr.pause_prefetch()
        self._mgr.set_capacity(plan.capacity)
        dt = time.perf_counter() - t0
        rung = "regrow_experts" if regrow else "trim_experts"
        if not regrow:
            FALLBACK_COUNTS["pressure_trim"] += 1
        self.rung_latency[rung] = dt
        self._event(rung, f"capacity {old.capacity}->{plan.capacity}", dt)

    def _apply_kv(self, plan: Plan, old: Plan, regrow: bool) -> None:
        pool = self.engine.pool
        if plan.pages == old.pages:
            return
        t0 = time.perf_counter()
        if plan.pages > pool.n_pages_usable:
            pool.restore_pages(plan.pages - pool.n_pages_usable)
            rung = "regrow_kv"
        else:
            rung = "retire_kv"
            FALLBACK_COUNTS["pressure_kv_retire"] += 1
            # free pages first; if the free list cannot cover the
            # shortfall, preempt the lowest-priority tenant (its pages
            # return to the free list) and retire again
            while pool.n_pages_usable > plan.pages:
                pool.retire_pages(pool.n_pages_usable - plan.pages)
                if pool.n_pages_usable <= plan.pages:
                    break
                if not self.engine.preempt_lowest():
                    break                 # nothing left to evict
        dt = time.perf_counter() - t0
        self.rung_latency[rung] = dt
        self._event(rung, f"pages {old.pages}->{pool.n_pages_usable}", dt)

    # -- observability -------------------------------------------------
    def _event(self, rung: str, detail: str, dt: float) -> None:
        self.events.append({"step": getattr(self.engine, "steps", -1),
                            "rung": rung, "detail": detail,
                            "seconds": dt})
        del self.events[:-self._MAX_EVENTS]

    def snapshot(self) -> dict:
        """For ``health()['pressure']`` — the applied plan, signal state,
        per-rung reclaim latency, and the event tail."""
        plan = getattr(self, "applied_plan", None)
        pool = self.engine.pool if self.engine is not None else None
        return {
            "target_bytes": self.target_bytes,
            "applied_bytes": self.applied_bytes,
            "boot_bytes": self.boot_bytes,
            "refusing": self.refusing,
            "refuse_below": getattr(self, "refuse_below", None),
            "plan": (plan._asdict() if plan is not None else None),
            "plan_changes": self.plan_changes,
            "grow_streak": self._grow_streak,
            "rung_latency_s": dict(self.rung_latency),
            "kv_device_bytes": (pool.device_bytes()
                                if pool is not None else None),
            "kv_pages_usable": (pool.n_pages_usable
                                if pool is not None else None),
            "events": self.events[-8:],
        }
