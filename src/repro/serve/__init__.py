"""Serving runtime: compressed-weight prefill/decode (the paper's system)."""
from .engine import ServeState, build_serve_params, make_serve_fns, generate

__all__ = ["ServeState", "build_serve_params", "make_serve_fns", "generate"]
