"""Serving runtime: compressed-weight prefill/decode (the paper's system)."""
from .engine import ServeState, build_serve_params, make_serve_fns, generate
from .resilience import (FALLBACK_COUNTS, DeadlineExceeded, ResiliencePolicy,
                         ResilientEngine, ServeRefused)

__all__ = ["ServeState", "build_serve_params", "make_serve_fns", "generate",
           "ResilientEngine", "ResiliencePolicy", "FALLBACK_COUNTS",
           "DeadlineExceeded", "ServeRefused"]
