"""Serving runtime: compressed-weight prefill/decode (the paper's system).

Two API levels:

  * **Request level** (preferred) — ``Engine.submit(Request) / step() /
    drain()``: a continuous-batching scheduler over a paged KV pool
    (``scheduler`` / ``kv_cache``).  Requests join and leave a running
    decode loop per engine tick; outputs are bitwise-equal to one-shot
    ``generate`` of the same prompt.  ``ResilientEngine.scheduler()``
    wraps every jitted step in the retry/degradation ladder.
  * **Fixed-batch compat** — ``make_serve_fns``/``generate`` serve one
    rectangular batch end-to-end; they remain the substrate the scheduler
    builds on (prefill closures, the sampling helper) and the surface the
    benchmarks and older drivers use.

``ServeContext`` bundles (cfg, mesh, lut, verify) for every entry point;
loose ``lut=``/``mesh=`` kwargs are deprecated.
"""
from .context import ServeContext
from .engine import (ServeState, build_serve_params, generate,
                     make_serve_fns, sample_tokens)
from .kv_cache import PagedKVPool
from .resilience import (FALLBACK_COUNTS, DeadlineExceeded, ResiliencePolicy,
                         ResilientEngine, ServeRefused)
from .scheduler import Completion, Engine, Request

__all__ = ["ServeState", "build_serve_params", "make_serve_fns", "generate",
           "sample_tokens", "ServeContext", "Engine", "Request", "Completion",
           "PagedKVPool", "ResilientEngine", "ResiliencePolicy",
           "FALLBACK_COUNTS", "DeadlineExceeded", "ServeRefused"]
