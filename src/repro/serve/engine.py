"""Serving runtime — compressed-weight inference, the paper's system.

Pipeline (paper §2.3 "inference", adapted per DESIGN.md §2):
  1. ``build_serve_params`` (host, offline): quantize every policy-selected
     weight to int8 per-channel, build ONE model-wide dictionary over the
     quantized byte streams, blocked-encode each tensor. Weights now live
     in HBM compressed.
  2. ``prefill`` / ``decode_step`` (device, jit): each layer decodes its
     weights on demand inside the forward graph (dict_decode → fused
     dequant-matmul), so peak HBM = compressed model + KV cache + one
     layer's working set — the paper's "decompress layer by layer",
     tile-granular on TPU.

Weight modes mirror the paper's evaluation triple:
  dense → "llama3.2-*", quant → "* Quantized", compressed → "* Compressed".
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CompressionPolicy, QuantConfig, build_lut,
                        encode_blocked, find_frequent_sequences,
                        quantize_linear)
from repro.core.compressed import PackedLinear, QuantLinear
from repro.core.blocked_codec import DEFAULT_BLOCK_WEIGHTS
from repro.models import lm as LM
from repro.models import encdec as ED
from repro.models import layers as L


@dataclasses.dataclass
class ServeState:
    params: Any
    lut: Optional[jax.Array]
    table: Optional[dict]
    mode: str
    stats: dict


def _iter_weight_paths(params):
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        yield jax.tree_util.keystr(path), leaf


def build_serve_params(params: Any, policy: CompressionPolicy,
                       *, qcfg: QuantConfig | None = None,
                       table: dict | None = None,
                       block_weights: int | None = None) -> ServeState:
    """Host-side conversion dense → quant/compressed per policy.

    Stacked (scanned) leaves keep their leading layer/expert dims: each
    sub-tensor is quantized per-channel and encoded separately, then the
    planes are re-stacked (uniform lit_cap across the stack).
    """
    qcfg = qcfg or QuantConfig(bits=policy.bits, granularity="per_channel")
    bw = block_weights or policy.block_weights
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)

    # Pass 1: decide actions; quantize selected tensors; gather byte streams.
    actions, quantized = [], {}
    streams = []
    for i, (path, leaf) in enumerate(flat):
        name = jax.tree_util.keystr(path)
        if not hasattr(leaf, "shape") or leaf.ndim < 2:
            actions.append("dense")
            continue
        shape2 = leaf.shape[-2:]         # per-layer dense shape
        act = policy.action(name, shape2)
        actions.append(act)
        if act in ("quant", "compressed"):
            stacked = leaf.reshape((-1,) + shape2)
            qls = [quantize_linear(stacked[j], qcfg)
                   for j in range(stacked.shape[0])]
            quantized[i] = qls
            if act == "compressed":
                streams.extend(np.asarray(q.values, dtype=np.uint8)
                               for q in qls)

    # Pass 2: one model-wide dictionary (paper: single table per model).
    if table is None and streams:
        table = find_frequent_sequences(streams, max_codes=65535)
    lut = None
    if table is not None:
        lut = jnp.asarray(build_lut(table))  # empty table → 1 zero row

    # Pass 3: build containers.
    new_leaves = []
    n_bytes = {"dense": 0, "quant": 0, "compressed": 0}
    for i, (path, leaf) in enumerate(flat):
        act = actions[i]
        if act == "dense":
            new_leaves.append(leaf)
            if hasattr(leaf, "nbytes"):
                n_bytes["dense"] += int(leaf.nbytes)
            continue
        qls = quantized[i]
        lead = leaf.shape[:-2]
        if act == "quant":
            vals = jnp.stack([q.values for q in qls]).reshape(
                lead + leaf.shape[-2:]).astype(jnp.uint8)
            sc = jnp.stack([q.scale for q in qls]).reshape(
                lead + (leaf.shape[-2], 1))
            zr = jnp.stack([q.zero for q in qls]).reshape(
                lead + (leaf.shape[-2], 1))
            new_leaves.append(QuantLinear(vals, sc, zr))
            n_bytes["quant"] += int(vals.nbytes + sc.nbytes + zr.nbytes)
        else:
            # encode each sub-tensor with a uniform literal capacity
            bcs = [encode_blocked(np.asarray(q.values, dtype=np.uint8),
                                  table, lut=np.asarray(lut),
                                  block_weights=bw) for q in qls]
            cap = max(bc.literals.shape[1] for bc in bcs)
            def padlit(bc):
                cur = bc.literals.shape[1]
                if cur == cap:
                    return bc.literals
                pad = jnp.zeros((bc.literals.shape[0], cap - cur,
                                 bc.literals.shape[2]), jnp.uint8)
                return jnp.concatenate([bc.literals, pad], axis=1)
            codes = jnp.stack([bc.codes for bc in bcs])
            lits = jnp.stack([padlit(bc) for bc in bcs])
            nlit = jnp.stack([bc.nlit for bc in bcs])
            sc = jnp.stack([q.scale for q in qls])
            zr = jnp.stack([q.zero for q in qls])
            if lead:
                codes = codes.reshape(lead + codes.shape[1:])
                lits = lits.reshape(lead + lits.shape[1:])
                nlit = nlit.reshape(lead + nlit.shape[1:])
                sc = sc.reshape(lead + sc.shape[1:])
                zr = zr.reshape(lead + zr.shape[1:])
            else:
                codes, lits, nlit = codes[0], lits[0], nlit[0]
                sc, zr = sc[0], zr[0]
            from repro.sharding.partition import (clean_keystr,
                                                  is_row_parallel)
            pl = PackedLinear(codes, lits, nlit, sc, zr,
                              shape=tuple(leaf.shape[-2:]),
                              row_parallel=is_row_parallel(
                                  clean_keystr(jax.tree_util.keystr(path))))
            new_leaves.append(pl)
            n_bytes["compressed"] += pl.payload_nbytes + int(
                sc.nbytes + zr.nbytes)

    params_out = treedef.unflatten(new_leaves)
    if lut is not None:
        n_bytes["compressed"] += int(lut.nbytes)
    mode = policy.mode
    return ServeState(params=params_out, lut=lut, table=table, mode=mode,
                      stats=n_bytes)


# ---------------------------------------------------------------------------
# jit-able step functions.
# ---------------------------------------------------------------------------

def make_serve_fns(cfg):
    """Returns (prefill, decode_step) closures for jit/pjit.

    prefill(params, lut, tokens_or_embeds, caches) -> (last_logits, caches)
    decode_step(params, lut, token, caches, pos) -> (logits, caches)
    """
    fam = cfg.family

    def _last_logits(params, hidden, lut=None):
        """LM head on the final position only — prefill never materializes
        (B, T, V) logits (25 GiB/dev at 32k×100k-vocab; §Perf iteration 3)."""
        head = params.get("lm_head", params.get("embed"))
        logits = L.linear(hidden[:, -1:], head, lut)
        if cfg.logits_softcap:
            c = cfg.logits_softcap
            logits = jnp.tanh(logits / c) * c
        return logits[:, 0]

    if fam == "encdec":
        def prefill(params, lut, batch, caches):
            hidden, new_caches = ED.forward(
                params, cfg, batch["enc_embeds"], batch["tokens"],
                caches=caches, pos=0, lut=lut, return_hidden=True)
            return _last_logits(params, hidden, lut), new_caches

        def decode_step(params, lut, token, caches, pos):
            logits, new_caches = ED.decode_step(params, cfg, token, caches,
                                                pos, lut=lut)
            return logits[:, -1], new_caches
        return prefill, decode_step

    def prefill(params, lut, batch, caches):
        hidden, new_caches, _ = LM.forward(
            params, cfg, batch.get("tokens"), embeds=batch.get("embeds"),
            caches=caches, pos=0, lut=lut, return_hidden=True)
        return _last_logits(params, hidden, lut), new_caches

    def decode_step(params, lut, token, caches, pos):
        logits, new_caches, _ = LM.forward(params, cfg, token, caches=caches,
                                           pos=pos, lut=lut)
        return logits[:, -1], new_caches

    return prefill, decode_step


def generate(params, cfg, tokens, *, lut=None, max_new: int = 16,
             max_len: int | None = None, temperature: float = 0.0,
             key=None, embeds=None):
    """Greedy/sampled generation loop (examples + accuracy benchmarks)."""
    b, t0 = tokens.shape
    extra = embeds.shape[1] if embeds is not None else 0
    max_len = max_len or (t0 + extra + max_new)
    caches = LM.init_caches(cfg, b, max_len)
    prefill, decode_step = make_serve_fns(cfg)
    logits, caches = prefill(params, lut,
                             {"tokens": tokens, "embeds": embeds}, caches)
    out = [tokens]
    pos = t0 + extra
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(tokens.dtype)
    for i in range(max_new):
        out.append(tok)
        if i == max_new - 1:
            break
        logits, caches = decode_step(params, lut, tok, caches, pos)
        if temperature > 0 and key is not None:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / temperature, axis=-1)[:, None].astype(tokens.dtype)
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(tokens.dtype)
        pos += 1
    return jnp.concatenate(out, axis=1)
