"""Serving runtime — compressed-weight inference, the paper's system.

Pipeline (paper §2.3 "inference", adapted per DESIGN.md §2):
  1. ``build_serve_params`` (host, offline): quantize every policy-selected
     weight to int8 per-channel, build ONE model-wide dictionary over the
     quantized byte streams, blocked-encode each tensor. Weights now live
     in HBM compressed.
  2. ``prefill`` / ``decode_step`` (device, jit): each layer decodes its
     weights on demand inside the forward graph via the fused
     decode→dequant→matmul megakernel (kernels/fused_decode_matmul.py),
     so peak HBM = compressed model + KV cache + one VMEM tile — the
     paper's "decompress layer by layer", tile-granular on TPU.  MoE
     expert stacks — where ~all of a QMoE-class model's bytes live — go
     through the grouped expert megakernel (one launch per stacked
     expert weight, expert grid axis; ``ops.grouped_decode_dequant_
     matmul``), extending the memory invariant to experts: peak HBM =
     compressed experts + capacity-gathered activations + one VMEM tile,
     with dense expert weights never materialized on any device.
     ``generate`` runs the whole decode phase under one jitted
     ``lax.scan`` so the kernel executes back-to-back with no per-token
     host sync or retrace.

Weight modes mirror the paper's evaluation triple:
  dense → "llama3.2-*", quant → "* Quantized", compressed → "* Compressed".

Request-level serving lives one layer up: ``serve.scheduler.Engine``
(continuous batching over a paged KV pool, ``submit``/``step``/``drain``)
reuses this module's ``prefill``/``decode_step`` closures and the shared
``sample_tokens`` rule, so its per-request outputs are bitwise-equal to
one-shot ``generate`` runs of the same prompts.  ``make_serve_fns`` and
``generate`` stay as the fixed-batch compatibility surface; both accept a
``ServeContext`` (serve/context.py) in place of the deprecated loose
``lut=``/``mesh=`` kwargs.

Resilience (core/integrity.py + serve/resilience.py): ``build_serve_
params`` also emits a per-plane integrity manifest (CRC32 over every
codes/literals/nlit/scale/zero plane, the model-wide LUT and the table)
stored on ``ServeState.manifest``.  The integrity invariant: when serving
runs with verification on (``launch/serve --verify fast|full``, or a
``ResiliencePolicy(verify=...)``), no compressed plane is decoded before
``verify_serve_state`` has re-hashed it against that manifest and the
device-side ``check_invariants`` pass (codes index inside the LUT, nlit
within literal capacity, finite affines) has run — corrupted leaves are
named and quarantined (``IntegrityError``), never silently decoded.
Runtime faults degrade instead of dying: ``ResilientEngine`` retries a
bounded number of times, then descends the ladder fused megakernel →
``impl='unfused'`` two-step → ``impl='materialize'`` dense einsum →
refuse-with-diagnostic, ticking ``resilience.FALLBACK_COUNTS`` per rung.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import warnings
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CompressionPolicy, QuantConfig, build_lut,
                        encode_blocked, find_frequent_sequences,
                        quantize_linear)
from repro.core.compressed import (PackedLinear, QuantLinear,
                                   TiledPackedLinear, encode_tiled_planes,
                                   pad_literals)
from repro.core import blocked_codec as bcdc
from repro.core.blocked_codec import DEFAULT_BLOCK_WEIGHTS
from repro.models import lm as LM
from repro.models import encdec as ED
from repro.models import layers as L


@dataclasses.dataclass
class ServeState:
    params: Any
    lut: Optional[jax.Array]
    table: Optional[dict]
    mode: str
    stats: dict
    # per-plane integrity manifest (core/integrity.py) recorded at pack
    # time; verify_serve_state re-hashes against it before serving.
    manifest: Optional[dict] = None


def _iter_weight_paths(params):
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        yield jax.tree_util.keystr(path), leaf


def build_serve_params(params: Any, policy: CompressionPolicy,
                       *, qcfg: QuantConfig | None = None,
                       table: dict | None = None,
                       block_weights: int | None = None,
                       model_shards: int = 1,
                       manifest: bool = True) -> ServeState:
    """Host-side conversion dense → quant/compressed per policy.

    Stacked (scanned) leaves keep their leading layer/expert dims: each
    sub-tensor is quantized per-channel and encoded separately, then the
    planes are re-stacked (uniform lit_cap across the stack).

    ``model_shards``: intended model-axis size of the serving mesh — the
    fused tile choice then divides the per-shard out dim so sharded
    serving dispatches to the shard-mapped fused megakernel instead of
    falling back to the two-step path (see ``ops.decode_dequant_matmul``).
    The same divisor is applied to stacked expert planes, so the per-model
    -shard slice of ``moe_d_ff`` stays tile-aligned for the grouped expert
    megakernel.  ``policy.tiles > 1`` stores eligible weights as
    TiledPackedLinear column tiles (2D-TP resident storage, §Perf D2),
    also tile-major — except expert stacks, which stay stacked
    PackedLinear (grouped-kernel eligible).

    ``manifest=True`` (default) records the per-plane integrity manifest
    (``core.integrity.build_manifest``) on the returned state so
    ``verify_serve_state`` can prove the artifact unchanged at load/boot.
    """
    qcfg = qcfg or QuantConfig(bits=policy.bits, granularity="per_channel")
    bw = block_weights or policy.block_weights
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)

    # Pass 1: decide actions; quantize selected tensors; gather byte streams.
    actions, quantized = [], {}
    streams = []
    for i, (path, leaf) in enumerate(flat):
        name = jax.tree_util.keystr(path)
        if not hasattr(leaf, "shape") or leaf.ndim < 2:
            actions.append("dense")
            continue
        shape2 = leaf.shape[-2:]         # per-layer dense shape
        act = policy.action(name, shape2)
        actions.append(act)
        if act in ("quant", "compressed"):
            stacked = leaf.reshape((-1,) + shape2)
            qls = [quantize_linear(stacked[j], qcfg)
                   for j in range(stacked.shape[0])]
            quantized[i] = qls
            if act == "compressed":
                streams.extend(np.asarray(q.values, dtype=np.uint8)
                               for q in qls)

    # Pass 2: one model-wide dictionary (paper: single table per model).
    if table is None and streams:
        table = find_frequent_sequences(streams, max_codes=65535)
    lut = None
    if table is not None:
        lut = jnp.asarray(build_lut(table))  # empty table → 1 zero row

    # Pass 3: build containers.
    new_leaves = []
    n_bytes = {"dense": 0, "quant": 0, "compressed": 0}
    for i, (path, leaf) in enumerate(flat):
        act = actions[i]
        if act == "dense":
            new_leaves.append(leaf)
            if hasattr(leaf, "nbytes"):
                n_bytes["dense"] += int(leaf.nbytes)
            continue
        qls = quantized[i]
        lead = leaf.shape[:-2]
        if act == "quant":
            vals = jnp.stack([q.values for q in qls]).reshape(
                lead + leaf.shape[-2:]).astype(jnp.uint8)
            sc = jnp.stack([q.scale for q in qls]).reshape(
                lead + (leaf.shape[-2], 1))
            zr = jnp.stack([q.zero for q in qls]).reshape(
                lead + (leaf.shape[-2], 1))
            new_leaves.append(QuantLinear(vals, sc, zr))
            n_bytes["quant"] += int(vals.nbytes + sc.nbytes + zr.nbytes)
        elif (policy.tiles > 1 and leaf.shape[-1] % policy.tiles == 0
              and "experts" not in jax.tree_util.keystr(path)):
            # 2D-TP column-tile storage, fused tile-major per tile.
            # Expert stacks are excluded: they stay stacked PackedLinear so
            # the grouped expert megakernel keeps them compressed-resident
            # under expert parallelism (column tiles would strand them on
            # the dense-materialize path).
            per = [encode_tiled_planes(
                np.asarray(q.values, dtype=np.uint8), table,
                np.asarray(lut), policy.tiles, block_weights=bw,
                tile="auto", shards=(model_shards, 1)) for q in qls]
            tn, tk = per[0][1], per[0][2]
            cap = max(bc.literals.shape[1]
                      for bcs, _, _ in per for bc in bcs)

            def stackplane(f):
                return jnp.stack([jnp.stack([f(bc) for bc in bcs])
                                  for bcs, _, _ in per])

            codes = stackplane(lambda bc: bc.codes)
            lits = stackplane(lambda bc: pad_literals(bc.literals, cap))
            nlit = stackplane(lambda bc: bc.nlit)
            sc = jnp.stack([q.scale for q in qls])
            zr = jnp.stack([q.zero for q in qls])
            if lead:
                codes = codes.reshape(lead + codes.shape[1:])
                lits = lits.reshape(lead + lits.shape[1:])
                nlit = nlit.reshape(lead + nlit.shape[1:])
                sc = sc.reshape(lead + sc.shape[1:])
                zr = zr.reshape(lead + zr.shape[1:])
            else:
                codes, lits, nlit = codes[0], lits[0], nlit[0]
                sc, zr = sc[0], zr[0]
            tl = TiledPackedLinear(codes, lits, nlit, sc, zr,
                                   shape=tuple(leaf.shape[-2:]),
                                   tile_n=tn, tile_k=tk)
            new_leaves.append(tl)
            n_bytes["compressed"] += tl.payload_nbytes + int(
                sc.nbytes + zr.nbytes)
        else:
            # Tile-major layout when the shape admits it, so serving hits
            # the fused decode→dequant→matmul megakernel; linear layout
            # (tile 0×0) otherwise → two-step fallback path.  The tile
            # choice divides the per-``model_shards`` out dim so the
            # shard-mapped fused path stays reachable on the target mesh.
            tiles = bcdc.choose_fused_tiles(leaf.shape[-2:], bw,
                                            shards=(model_shards, 1))
            tn, tk = tiles[:2] if tiles else (0, 0)
            # encode each sub-tensor with a uniform literal capacity
            if tiles:
                bcs = [bcdc.encode_blocked_tiled(
                    np.asarray(q.values, dtype=np.uint8), table,
                    lut=np.asarray(lut), tile_n=tn, tile_k=tk,
                    block_weights=bw) for q in qls]
            else:
                bcs = [encode_blocked(np.asarray(q.values, dtype=np.uint8),
                                      table, lut=np.asarray(lut),
                                      block_weights=bw) for q in qls]
            cap = max(bc.literals.shape[1] for bc in bcs)
            codes = jnp.stack([bc.codes for bc in bcs])
            lits = jnp.stack([pad_literals(bc.literals, cap) for bc in bcs])
            nlit = jnp.stack([bc.nlit for bc in bcs])
            sc = jnp.stack([q.scale for q in qls])
            zr = jnp.stack([q.zero for q in qls])
            if lead:
                codes = codes.reshape(lead + codes.shape[1:])
                lits = lits.reshape(lead + lits.shape[1:])
                nlit = nlit.reshape(lead + nlit.shape[1:])
                sc = sc.reshape(lead + sc.shape[1:])
                zr = zr.reshape(lead + zr.shape[1:])
            else:
                codes, lits, nlit = codes[0], lits[0], nlit[0]
                sc, zr = sc[0], zr[0]
            from repro.sharding.partition import (clean_keystr,
                                                  is_row_parallel)
            pl = PackedLinear(codes, lits, nlit, sc, zr,
                              shape=tuple(leaf.shape[-2:]),
                              row_parallel=is_row_parallel(
                                  clean_keystr(jax.tree_util.keystr(path))),
                              tile_n=tn, tile_k=tk)
            new_leaves.append(pl)
            n_bytes["compressed"] += pl.payload_nbytes + int(
                sc.nbytes + zr.nbytes)

    params_out = treedef.unflatten(new_leaves)
    if lut is not None:
        n_bytes["compressed"] += int(lut.nbytes)
    mode = policy.mode
    mf = None
    if manifest:
        from repro.core import integrity
        mf = integrity.build_manifest(params_out, lut, table)
    return ServeState(params=params_out, lut=lut, table=table, mode=mode,
                      stats=n_bytes, manifest=mf)


# ---------------------------------------------------------------------------
# jit-able step functions.
# ---------------------------------------------------------------------------

# Python-body execution counts of the serve closures — a body runs once per
# jit (re)trace, so tests can assert the decode loop traces once instead of
# once per token.  Keyed by closure name.
TRACE_COUNTS = collections.Counter()


def make_serve_fns(cfg=None, *, jit: bool = True, mesh=None, ctx=None):
    """Returns (prefill, decode_step) for serving.

    prefill(params, lut, tokens_or_embeds, caches) -> (last_logits, caches)
    decode_step(params, lut, token, caches, pos) -> (logits, caches)

    By default the closures come back jit-compiled and cached per config
    (``lut``/``params`` are ordinary traced arguments), so repeated callers
    — ``examples/serve_batched.py``, ``benchmarks/latency.py`` — never
    re-trace per call.  ``jit=False`` returns the raw closures for callers
    that apply their own pjit shardings (launch/dryrun) or embed the step
    in a larger traced computation (the ``generate`` scan loop / the
    scheduler's ``generate_step``).

    ``ctx``: a ``ServeContext`` — the preferred way to carry (cfg, mesh);
    passing ``mesh`` loosely still works but is deprecated (warns).  A
    concrete mesh is made visible (``partition.active_mesh``) at trace
    time, so in-graph constraints and the shard-mapped fused
    decode→dequant→matmul paths see it; the jit cache keys on (cfg, mesh),
    so mesh-less and sharded closures never share a stale trace.

    ``decode_step``'s ``pos`` is a scalar offset shared by the whole batch
    *or* a per-row (B,) vector (the continuous-batching paged view — see
    ``models.layers._kv_write`` / ``serve.scheduler``).
    """
    if ctx is not None:
        cfg = ctx.cfg if cfg is None else cfg
        mesh = ctx.mesh
        if getattr(ctx, "residency", None) is not None:
            # Tiered expert residency: the returned closures run each step
            # through the ResidencyManager's fetch/replay protocol (always
            # jitted inside — see serve/residency.py).
            from repro.serve import residency as _res
            return _res.make_tiered_serve_fns(
                ctx if cfg is ctx.cfg else ctx.with_cfg(cfg))
    elif mesh is not None:
        _warn_loose_kwargs("make_serve_fns")
    if jit:
        return _jitted_serve_fns(cfg, mesh)
    return _raw_serve_fns(cfg)


def _mesh_ctx(mesh):
    from repro.sharding.partition import active_mesh
    return active_mesh(mesh) if mesh is not None else contextlib.nullcontext()


@functools.lru_cache(maxsize=None)
def _jitted_serve_fns(cfg, mesh=None):
    prefill, decode_step = _raw_serve_fns(cfg)

    def wrap(fn):
        @jax.jit
        def wrapped(*args):
            with _mesh_ctx(mesh):   # trace-time: constraints see the mesh
                return fn(*args)
        return wrapped

    if mesh is None:
        return jax.jit(prefill), jax.jit(decode_step)
    return wrap(prefill), wrap(decode_step)


def _raw_serve_fns(cfg, routing: bool = False):
    """``routing=True`` (MoE only): prefill/decode_step additionally return
    the per-layer top-k expert ids — (L_moe, n_tok, k) int32 — so the
    tiered residency manager can plan fetches from the step it just ran
    (serve/residency.py)."""
    fam = cfg.family
    if routing and fam == "encdec":
        raise ValueError("routing capture is not supported for encdec")

    def _last_logits(params, hidden, lut=None):
        """LM head on the final position only — prefill never materializes
        (B, T, V) logits (25 GiB/dev at 32k×100k-vocab; §Perf iteration 3)."""
        head = params.get("lm_head", params.get("embed"))
        logits = L.linear(hidden[:, -1:], head, lut)
        if cfg.logits_softcap:
            c = cfg.logits_softcap
            logits = jnp.tanh(logits / c) * c
        return logits[:, 0]

    if fam == "encdec":
        def prefill(params, lut, batch, caches):
            TRACE_COUNTS["prefill"] += 1
            hidden, new_caches = ED.forward(
                params, cfg, batch["enc_embeds"], batch["tokens"],
                caches=caches, pos=0, lut=lut, return_hidden=True)
            return _last_logits(params, hidden, lut), new_caches

        def decode_step(params, lut, token, caches, pos):
            TRACE_COUNTS["decode_step"] += 1
            logits, new_caches = ED.decode_step(params, cfg, token, caches,
                                                pos, lut=lut)
            return logits[:, -1], new_caches
        return prefill, decode_step

    if routing:
        def prefill_r(params, lut, batch, caches):
            TRACE_COUNTS["prefill"] += 1
            hidden, new_caches, _, eids = LM.forward(
                params, cfg, batch.get("tokens"),
                embeds=batch.get("embeds"), caches=caches, pos=0, lut=lut,
                return_hidden=True, return_routing=True)
            return _last_logits(params, hidden, lut), new_caches, eids

        def decode_step_r(params, lut, token, caches, pos):
            TRACE_COUNTS["decode_step"] += 1
            logits, new_caches, _, eids = LM.forward(
                params, cfg, token, caches=caches, pos=pos, lut=lut,
                return_routing=True)
            return logits[:, -1], new_caches, eids

        return prefill_r, decode_step_r

    def prefill(params, lut, batch, caches):
        TRACE_COUNTS["prefill"] += 1
        hidden, new_caches, _ = LM.forward(
            params, cfg, batch.get("tokens"), embeds=batch.get("embeds"),
            caches=caches, pos=0, lut=lut, return_hidden=True)
        return _last_logits(params, hidden, lut), new_caches

    def decode_step(params, lut, token, caches, pos):
        TRACE_COUNTS["decode_step"] += 1
        logits, new_caches, _ = LM.forward(params, cfg, token, caches=caches,
                                           pos=pos, lut=lut)
        return logits[:, -1], new_caches

    return prefill, decode_step


def sample_tokens(logits, temperature, key=None):
    """The one next-token rule for every decode path.

    The legacy one-shot loop (``_decode_loop``) and the continuous-batching
    ``scheduler._generate_step`` both sample through here, so greedy /
    temperature sampling cannot drift between the two — single-request
    parity between them is *bitwise*.

    logits: (B, V).  Three modes:
      * ``key=None`` or scalar ``temperature <= 0`` → greedy argmax.
      * scalar ``temperature`` + key → ``categorical(key, logits / T)``
        (identical to the historical in-loop sampling).
      * array ``temperature`` (B,) + per-row keys (B, 2) → vmapped
        per-row categorical; rows with temperature 0 take the argmax
        result exactly (bitwise equal to the greedy path).
    Returns (B,) token ids.
    """
    greedy = jnp.argmax(logits, axis=-1)
    if key is None:
        return greedy
    if jnp.ndim(temperature) == 0:
        if isinstance(temperature, (int, float)) and temperature <= 0:
            return greedy
        return jax.random.categorical(key, logits / temperature, axis=-1)
    temp = jnp.asarray(temperature, jnp.float32)
    sampled = jax.vmap(jax.random.categorical)(
        key, logits / jnp.maximum(temp, 1e-6)[:, None])
    return jnp.where(temp > 0, sampled, greedy)


@partial(jax.jit, static_argnums=(0, 1, 2, 3))
def _decode_loop(cfg, steps: int, temperature: float, mesh,
                 params, lut, tok0, caches, pos0, key):
    """``steps`` decode steps under one ``lax.scan`` — a single trace and a
    single device program for the whole decode phase, instead of one
    host-synced dispatch (and, un-jitted, one retrace) per token.  ``mesh``
    (static, hashable) scopes the trace under ``active_mesh`` so sharded
    decode runs the same single program through the shard-mapped fused
    kernel paths."""
    TRACE_COUNTS["decode_loop"] += 1
    _, decode_step = _raw_serve_fns(cfg)
    sample = temperature > 0 and key is not None

    def step(carry, _):
        tok, caches, pos, key = carry
        logits, caches = decode_step(params, lut, tok, caches, pos)
        if sample:
            key, sub = jax.random.split(key)
            nxt = sample_tokens(logits, temperature,
                                sub)[:, None].astype(tok.dtype)
        else:
            nxt = sample_tokens(logits, 0.0)[:, None].astype(tok.dtype)
        return (nxt, caches, pos + 1, key), nxt

    init = (tok0, caches, jnp.asarray(pos0, jnp.int32), key)
    with _mesh_ctx(mesh):
        _, toks = jax.lax.scan(step, init, None, length=steps)
    return jnp.swapaxes(toks[..., 0], 0, 1)        # (steps, B, 1) -> (B, steps)


def _warn_loose_kwargs(caller: str):
    warnings.warn(
        f"{caller}: loose lut=/mesh= kwargs are deprecated — pass "
        "ctx=ServeContext(cfg, mesh=..., lut=...) (repro.serve.context) "
        "instead", DeprecationWarning, stacklevel=3)


def generate(params, cfg, tokens, *, ctx=None, lut=None, max_new: int = 16,
             max_len: int | None = None, temperature: float = 0.0,
             key=None, embeds=None, mesh=None):
    """One-shot greedy/sampled generation (examples + accuracy benchmarks).

    Prefill runs once under jit; the decode phase is a single jitted
    ``lax.scan`` over ``decode_step`` (see ``_decode_loop``), so compressed
    layers hit the fused decode→dequant→matmul kernel back-to-back with no
    per-token host sync or retrace.  Serve sharded by passing a mesh (via
    ``ctx``): the same single-trace loop then dispatches through the
    shard-mapped fused paths (see ``ops.decode_dequant_matmul``).

    ``ctx``: a ``ServeContext`` carrying (cfg, mesh, lut) — the preferred
    spelling; the loose ``lut=``/``mesh=`` kwargs remain as a deprecated
    compatibility path (they warn).  For request-level serving — admission
    into a running batch, per-request completion — use
    ``serve.scheduler.Engine`` instead; this entry point stays the
    fixed-batch reference the scheduler's outputs are bitwise-checked
    against.
    """
    if ctx is not None:
        cfg = ctx.cfg if cfg is None else cfg
        lut, mesh = ctx.lut, ctx.mesh
        if getattr(ctx, "residency", None) is not None:
            # Tiered expert residency: a host-stepped decode loop through
            # the ResidencyManager (bitwise-equal to this scan loop — the
            # per-step jitted program is the same computation; see
            # serve/residency.py and tests/test_residency.py).
            from repro.serve import residency as _res
            return _res.tiered_generate(
                params, cfg, tokens, ctx=ctx, max_new=max_new,
                max_len=max_len, temperature=temperature, key=key,
                embeds=embeds)
    elif lut is not None or mesh is not None:
        _warn_loose_kwargs("generate")
    if max_new <= 0:
        return tokens
    b, t0 = tokens.shape
    extra = embeds.shape[1] if embeds is not None else 0
    max_len = max_len or (t0 + extra + max_new)
    caches = LM.init_caches(cfg, b, max_len)
    from repro.serve.context import ServeContext
    prefill, _ = make_serve_fns(ctx=ServeContext(cfg=cfg, mesh=mesh, lut=lut))
    logits, caches = prefill(params, lut,
                             {"tokens": tokens, "embeds": embeds}, caches)
    tok0 = sample_tokens(logits, 0.0)[:, None].astype(tokens.dtype)
    if max_new <= 1:
        return jnp.concatenate([tokens, tok0], axis=1)
    toks = _decode_loop(cfg, max_new - 1, float(temperature), mesh,
                        params, lut, tok0, caches, t0 + extra, key)
    return jnp.concatenate([tokens, tok0, toks.astype(tokens.dtype)], axis=1)
