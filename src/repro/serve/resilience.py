"""Resilient serving — bounded retry, deadlines, and a degradation ladder.

The serve stack's production posture (the inference-side mirror of
``train/fault.py``): a request against a compressed model must never die
on the first ``JaxRuntimeError`` or silently serve from a corrupt
artifact.  ``ResilientEngine`` wraps ``engine.prefill``/``engine.generate``
with:

  * **Integrity gate** — per ``ResiliencePolicy.verify`` ('off'|'fast'|
    'full'), the artifact is host-verified against its pack-time manifest
    (``core.integrity.verify_serve_state``) and the cheap jittable
    device-side invariant check (``check_invariants``) runs before the
    first prefill.  Quarantined leaves abort serving with
    ``IntegrityError`` naming them — no decode of unverified planes while
    verification is on.
  * **Bounded retry** — each ladder rung is attempted up to
    ``max_retries + 1`` times on ``jax.errors.JaxRuntimeError`` (transient
    device faults recover in place, exactly like the train loop's step
    retry).
  * **Degradation ladder** — persistent failures descend
    ``fused`` (megakernel) → ``unfused`` (two-step decode→matmul) →
    ``materialize`` (pure-jnp decode + dense einsum, no Pallas anywhere)
    → refuse with ``ServeRefused`` carrying the per-rung diagnostics.
    Each fallback ticks ``FALLBACK_COUNTS`` (alongside the existing
    ``ops.DISPATCH_COUNTS`` / ``engine.TRACE_COUNTS`` probes) so CI and
    the health snapshot can prove which rungs ran.  Rungs re-trace under a
    suffixed config name — the jit caches key on (cfg, mesh), so a broken
    fused trace is never reused by a fallback rung.
  * **Per-request deadline** — ``deadline_s`` (policy or per-call) bounds
    the whole retry/ladder walk; expiry raises ``DeadlineExceeded``
    instead of burning the remaining rungs.

The same machinery covers the continuous-batching path:
``ResilientEngine.scheduler()`` returns a ``serve.scheduler.Engine`` whose
jitted prefill and ``generate_step`` calls each walk the ladder through
the ``_guard`` hook — one faulty decode tick degrades (and re-traces)
without tearing down the whole serving loop or its co-tenant requests.
When even the ladder's last rung fails for a batched tick (a *poisoned
request*, not a broken kernel), the scheduler takes over: it bisects the
active slots with masked replays of the same jitted step, refuses only
the culprit (``ServeRefused`` semantics at request granularity,
``FALLBACK_COUNTS['quarantine']``), and requeues the healthy survivors —
the guard's ``kind`` is 'replay' for those probes.  Overload events the
scheduler accounts for (shed / expired / preempt) tick the same counter,
so ``health()['fallbacks']`` is the one place CI asserts the whole
robustness matrix.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Optional

import jax

from repro.core.integrity import (IntegrityError, check_invariants,
                                  verify_serve_state)
from repro.kernels import ops
from repro.serve import engine as _engine

# Degradation probe: rung/event -> count.  'unfused'/'materialize' tick
# when the ladder *falls back* onto that rung; 'retry:<rung>' per bounded
# in-rung retry; 'deadline' on expiry; 'refused' when the ladder is
# exhausted; 'integrity_refused' when the verify gate quarantines the
# artifact.  The request-level scheduler (serve/scheduler.py) ticks its
# own lifecycle events here too so one counter tells the whole
# degradation story: 'quarantine' per poisoned request refused out of a
# batch, 'preempt' per in-flight request evicted under page pressure,
# 'shed' per request shed by the bounded queue, 'expired' per TTL /
# deadline expiry.  The memory-pressure governor (serve/governor.py)
# ticks 'pressure_*' keys: 'pressure_trim' per residency-capacity trim,
# 'pressure_kv_retire' per KV page-retirement batch, 'pressure_preempt'
# per in-flight request evicted to shrink the pool, 'pressure_tighten'
# per admission tightening, 'pressure_refused' per submission refused at
# rung 4, 'pressure_regrow' per regrow-ladder application.  Reset
# between tests by the autouse conftest fixture.
FALLBACK_COUNTS = collections.Counter()

# Ladder rung -> the ops session impl that forces it.  'fused' serves with
# the session default ('auto': megakernel dispatch); the fallbacks pin the
# lever so every compressed matmul in the re-traced program takes the rung.
_RUNG_IMPL = {ops.FUSED_RUNG: None,
              ops.Impl.UNFUSED.value: ops.Impl.UNFUSED.value,
              ops.Impl.MATERIALIZE.value: ops.Impl.MATERIALIZE.value}


class DeadlineExceeded(TimeoutError):
    """Per-request wall-clock budget expired mid retry/ladder walk."""


class ServeRefused(RuntimeError):
    """Every ladder rung failed; carries the per-rung diagnostics."""

    def __init__(self, errors):
        self.errors = list(errors)        # [(rung, attempt, repr(exc))]
        super().__init__(
            "degradation ladder exhausted: "
            + "; ".join(f"{r}#{a}: {e}" for r, a, e in self.errors))


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    max_retries: int = 1                  # per rung, on JaxRuntimeError
    deadline_s: float = 0.0               # 0 = no per-request deadline
    ladder: tuple = ops.DEFAULT_LADDER
    verify: str = "off"                   # off | fast | full (boot gate)


def _generate(params, cfg, tokens, **kw):
    """Seam for fault injection/tests — resolves to ``engine.generate``."""
    return _engine.generate(params, cfg, tokens, **kw)


def _prefill(cfg, mesh, params, lut, batch, caches, residency=None):
    """Seam mirroring :func:`_generate` for the prefill path."""
    from repro.serve.context import ServeContext
    prefill, _ = _engine.make_serve_fns(
        ctx=ServeContext(cfg=cfg, mesh=mesh, lut=lut, residency=residency))
    return prefill(params, lut, batch, caches)


class ResilientEngine:
    """Fault-covered front door over (ServeState, cfg) serving.

    ``state`` is an ``engine.ServeState`` (or any object with ``params``/
    ``lut``/``manifest`` attributes).  The integrity gate runs once at
    construction per ``policy.verify``; ``generate``/``prefill`` then walk
    the retry/deadline/ladder machinery per request.
    """

    def __init__(self, cfg, state, *, policy: ResiliencePolicy | None = None,
                 mesh=None, residency=None):
        self.cfg = cfg
        self.state = state
        self.mesh = mesh
        # Optional serve.residency.ResidencyManager: tiered expert
        # residency (host-RAM backing + HBM cache).  Threaded into every
        # ServeContext this engine builds, so one cache serves generate,
        # the scheduler, and every degradation-ladder rung; fetch faults
        # raise JaxRuntimeError host-side and walk the same ladder.
        self.residency = residency
        if residency is not None and mesh is not None:
            raise ValueError("tiered residency is single-device — "
                             "mesh must be None")
        self.policy = policy or ResiliencePolicy()
        self.verify_report = None
        self.invariant_report = None
        self.requests = 0
        self.last_rung: Optional[str] = None
        self._history: list = []          # [(rung, attempt, repr(exc))]
        if self.policy.verify != "off":
            self._integrity_gate()

    # -- integrity -----------------------------------------------------
    def _integrity_gate(self):
        """Host re-hash + device-side invariants before any decode."""
        self.verify_report = verify_serve_state(self.state,
                                                level=self.policy.verify)
        if not self.verify_report.ok:
            FALLBACK_COUNTS["integrity_refused"] += 1
            raise IntegrityError(self.verify_report)
        self.invariant_report = check_invariants(self.state)
        if not self.invariant_report.ok:
            FALLBACK_COUNTS["integrity_refused"] += 1
            raise IntegrityError(self.invariant_report)

    # -- rung plumbing -------------------------------------------------
    def _rung_cfg(self, rung: str):
        """Fallback rungs serve under a suffixed config name: the serve jit
        caches key on (cfg, mesh), so the fallback re-traces with the
        session impl lever pinned instead of reusing the faulty trace."""
        if rung == self.policy.ladder[0]:
            return self.cfg
        return dataclasses.replace(self.cfg,
                                   name=f"{self.cfg.name}+{rung}")

    @staticmethod
    def _effects_barrier():
        """Surface host-callback/ordered-effect faults as JaxRuntimeError.

        A failing host callback inside a jitted program parks its error on
        the ordered-effects *token*, not (reliably) on the value outputs —
        the custom-call thunks feeding Pallas kernels drop input error
        events — and jax only awaits tokens at interpreter exit.  Draining
        here turns that deferred crash into a catchable per-request fault;
        the poisoned token is cleared so fallback rungs start clean."""
        from jax._src import dispatch as _dispatch
        try:
            jax.effects_barrier()
        except jax.errors.JaxRuntimeError:
            _dispatch.runtime_tokens.clear()
            raise

    def _run_rung(self, rung: str, fn, *args, **kw):
        lever = _RUNG_IMPL.get(rung)
        prev = ops._DEFAULT_IMPL
        try:
            if lever is not None:
                ops.set_default_impl(lever)
            out = fn(*args, **kw)
            jax.block_until_ready(out)    # surface faults inside the rung
            self._effects_barrier()
            return out
        except jax.errors.JaxRuntimeError:
            # The fault may be parked on BOTH the value outputs and the
            # ordered-effects token; drain the token here so a stale
            # poisoned one can't fail the next (healthy) rung.
            try:
                self._effects_barrier()
            except jax.errors.JaxRuntimeError:
                pass
            raise
        finally:
            ops.set_default_impl(prev)

    def _deadline_check(self, t0: float, deadline: float):
        if deadline and time.monotonic() - t0 > deadline:
            FALLBACK_COUNTS["deadline"] += 1
            raise DeadlineExceeded(
                f"request exceeded {deadline:.3f}s "
                f"(elapsed {time.monotonic() - t0:.3f}s; "
                f"history {self._history[-4:]})")

    def _with_ladder(self, make_call, *, deadline_s: Optional[float]):
        """Retry/ladder walk shared by generate and prefill.

        ``make_call(rung)`` returns a zero-arg callable for that rung.
        """
        deadline = (self.policy.deadline_s if deadline_s is None
                    else deadline_s)
        t0 = time.monotonic()
        errors = []
        self.requests += 1
        for i, rung in enumerate(self.policy.ladder):
            if i > 0:
                FALLBACK_COUNTS[rung] += 1
            for attempt in range(self.policy.max_retries + 1):
                self._deadline_check(t0, deadline)
                if attempt > 0:
                    FALLBACK_COUNTS[f"retry:{rung}"] += 1
                try:
                    out = self._run_rung(rung, make_call(rung))
                    self.last_rung = rung
                    return out
                except jax.errors.JaxRuntimeError as e:
                    rec = (rung, attempt, f"{type(e).__name__}: {e}"[:200])
                    errors.append(rec)
                    self._history.append(rec)
        FALLBACK_COUNTS["refused"] += 1
        raise ServeRefused(errors)

    # -- public API ----------------------------------------------------
    def generate(self, tokens, *, max_new: int = 16, temperature: float = 0.0,
                 key=None, embeds=None, max_len: int | None = None,
                 deadline_s: float | None = None):
        from repro.serve.context import ServeContext

        def make_call(rung):
            cfg = self._rung_cfg(rung)
            ctx = ServeContext(cfg=cfg, mesh=self.mesh, lut=self.state.lut,
                               verify=self.policy.verify,
                               residency=self.residency)
            return lambda: _generate(self.state.params, cfg, tokens,
                                     ctx=ctx, max_new=max_new,
                                     max_len=max_len,
                                     temperature=temperature, key=key,
                                     embeds=embeds)
        return self._with_ladder(make_call, deadline_s=deadline_s)

    def prefill(self, batch, caches, *, deadline_s: float | None = None):
        def make_call(rung):
            cfg = self._rung_cfg(rung)
            return lambda: _prefill(cfg, self.mesh, self.state.params,
                                    self.state.lut, batch, caches,
                                    residency=self.residency)
        return self._with_ladder(make_call, deadline_s=deadline_s)

    def _guard(self, call, kind: str):
        """Scheduler guard hook: run one jitted engine call (``call(cfg)``,
        kind 'prefill'|'decode'|'replay') under the retry/deadline/ladder
        walk.  Each rung substitutes its suffixed config, so a broken fused
        generate_step re-traces unfused instead of reusing the bad trace.
        'replay' calls are the quarantine bisect's masked sub-batch probes:
        they walk the same ladder, so a probe only reports a subset faulty
        when no rung can serve it — exactly the culprit criterion."""
        return self._with_ladder(
            lambda rung: (lambda: call(self._rung_cfg(rung))),
            deadline_s=None)

    def scheduler(self, **engine_kw):
        """A continuous-batching ``scheduler.Engine`` whose every jitted
        prefill/decode step walks this engine's resilience ladder.  Keyword
        args (``n_slots``, ``max_len``, ``page_size``, ``governor``, ...)
        pass through; the built engine is remembered so ``health()`` /
        ``close()`` cover it."""
        from repro.serve.context import ServeContext
        from repro.serve import scheduler as _sched
        ctx = ServeContext(cfg=self.cfg, mesh=self.mesh, lut=self.state.lut,
                           verify=self.policy.verify,
                           residency=self.residency)
        self._scheduler = _sched.Engine(ctx, self.state.params,
                                        guard=self._guard, **engine_kw)
        return self._scheduler

    def close(self) -> None:
        """Tear down serving workers (residency prefetch thread) —
        idempotent; also usable as a context manager."""
        sched = getattr(self, "_scheduler", None)
        if sched is not None:
            sched.close()
        elif self.residency is not None:
            self.residency.close()

    def __enter__(self) -> "ResilientEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def health(self) -> dict:
        """Snapshot for operators/CI: verify + probe counters + last rung.
        Under tiered residency, includes the manager's hit/miss/prefetch/
        eviction/bytes-fetched snapshot alongside the fallback counters."""
        out = {
            "requests": self.requests,
            "last_rung": self.last_rung,
            "fallbacks": dict(FALLBACK_COUNTS),
            "dispatch": dict(ops.DISPATCH_COUNTS),
            "verify": (self.verify_report.summary()
                       if self.verify_report else None),
            "invariants": (self.invariant_report.summary()
                           if self.invariant_report else None),
            "recent_errors": self._history[-8:],
        }
        if self.residency is not None:
            out["residency"] = self.residency.snapshot()
        sched = getattr(self, "_scheduler", None)
        if sched is not None and sched.governor is not None:
            out["pressure"] = sched.governor.snapshot()
        return out
