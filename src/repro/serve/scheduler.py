"""Continuous-batching scheduler — the request-level serving API.

The one-shot ``engine.generate`` runs a fixed batch through a single
``lax.scan``: no request can join or leave a running decode loop, so real
traffic (staggered arrivals, varied lengths) serializes.  This module is
the serving front door built on the prefill→insert→generate-step split:

  * ``Request``/``Completion`` — the public dataclasses.  A request is a
    prompt plus decode budget (``max_new``), optional ``eos_id``, sampling
    controls, a ``priority`` (preemption rank), and an optional TTL /
    wall-clock deadline enforced from ``submit()`` time; a completion
    carries the full ``generate``-shaped token sequence plus lifecycle
    metadata (submit/finish step, reason).
  * ``Engine.submit(request)`` — queue a request (returns its rid).  The
    queue is *bounded* when ``max_queue`` is set: overloading it sheds a
    request per the ``shed_policy`` ('reject-new' sheds the submission,
    'drop-oldest' sheds the head of the queue) as a
    ``Completion(finished='shed')`` — overload produces accounted-for
    completions, never an unbounded queue.
  * ``Engine.step()`` — one engine tick: expire queued/in-flight requests
    whose TTL or deadline passed (``finished='deadline'``), admit queued
    requests into free decode slots (jitted prefill into a cache
    *fragment*, then ``kv_cache.insert_fragment`` into the slot's pages),
    advance every occupied slot one token with the jitted
    ``_generate_step``, and retire slots that hit EOS or their ``max_new``
    budget — freeing their pages for the next queued request.  Returns the
    requests completed by this tick.
  * ``Engine.drain()`` — step until queue and slots are empty.

``_generate_step`` is jitted once per (cfg, mesh): the paged view, the
per-slot position vector, the active mask, and the page table are all
*traced* values, so admissions and completions never retrace.  Each tick
advances all occupied slots with per-slot position/length masks — vacant
slots compute garbage that is masked out of storage by the
``write_token`` OOB-drop scatter.

Fault isolation (the request-level robustness layer):

  * **Poisoned-request quarantine** — when a batched decode tick still
    fails after the guard (for a bare ``Engine``, a raw
    ``JaxRuntimeError``; under ``ResilientEngine.scheduler()``, a
    ``ServeRefused`` after the whole degradation ladder), the engine
    *bisects* the active slots by replaying masked sub-batches through
    the already-jitted step — active masks are traced values, so the
    probes reuse the existing trace — refuses only the culprit request(s)
    (``finished='refused'``, ``FALLBACK_COUNTS['quarantine']``), and
    requeues the healthy survivors with their accumulated tokens.
    Survivors resume via a fresh prefill of prompt + generated-so-far
    (device state after a fault is suspect; host tokens are the truth),
    and the resumed stream is bitwise-identical to an uninterrupted run
    because sampling keys fold in the *absolute* position.
  * **Preempt under page pressure** — when the page pool cannot back an
    admission (overcommitted ``n_pages``, or injected alloc failure), the
    lowest-priority/youngest in-flight request is evicted back to the
    queue (``FALLBACK_COUNTS['preempt']``), its pages reclaimed for the
    higher-priority candidate; the victim resumes later through the same
    re-prefill path.  Preemption requires *strictly* lower victim
    priority, so equal-priority traffic can never livelock-swap.

Parity invariant (the acceptance bar): a request served through the
engine — including one that was preempted or survived a quarantine —
yields tokens bitwise-equal to ``engine.generate`` of the same prompt
with ``max_len=engine.pool.max_len``.  The ingredients: prefill uses the
*same* jitted closure over the same cache shape; masked cache entries
(-1e30 → exp underflows to exactly 0.0) contribute nothing to the
softmax sums regardless of what stale pages hold; both paths sample
through ``engine.sample_tokens``; and per-request PRNG keys fold in the
absolute position, so a resume at position P samples exactly what the
uninterrupted run sampled at P.  MoE configs additionally need the
dropless regime (``capacity_factor >= n_experts / top_k``) — expert
capacity depends on batch size, so capacity *drops* may differ between
batch shapes.

``ResilientEngine.scheduler()`` wraps every jitted step in the
retry/deadline/degradation ladder via the ``guard`` hook — see
serve/resilience.py and docs/serving.md.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from functools import partial
from typing import Any, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import lm as LM
from repro.serve import engine as _engine
from repro.serve.context import ServeContext
from repro.serve.kv_cache import (PagedKVPool, PoolExhausted, paged_view,
                                  write_token)
from repro.serve.resilience import FALLBACK_COUNTS, ServeRefused

# What the robustness layer treats as "this jitted call faulted": a raw
# device fault (bare Engine) or an exhausted degradation ladder
# (ResilientEngine guard).  DeadlineExceeded et al. still propagate.
_FAULTS = (jax.errors.JaxRuntimeError, ServeRefused)

SHED_POLICIES = ("reject-new", "drop-oldest")


@dataclasses.dataclass
class Request:
    """One generation request.

    tokens: (T,) int prompt.  max_new: decode budget, generated tokens
    including the one the prefill emits.  eos_id: stop token (the emitted
    sequence includes it).  temperature/seed: sampling controls — the
    per-request PRNG is folded with the absolute position each step, so
    tokens are reproducible regardless of slot placement, co-tenants, or
    preempt/resume cycles.  priority: preemption rank (higher wins; a
    queued request may evict a strictly-lower-priority in-flight one
    under page pressure).  ttl_steps / deadline_s: expiry measured from
    ``submit()`` in engine steps / wall-clock seconds — an expired
    request completes with ``finished='deadline'`` instead of waiting
    forever (ttl_steps=None defers to the engine-wide ``request_ttl``).
    """
    tokens: Any
    max_new: int = 16
    eos_id: Optional[int] = None
    temperature: float = 0.0
    seed: int = 0
    rid: Optional[int] = None          # assigned by submit() when None
    priority: int = 0
    ttl_steps: Optional[int] = None
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class Completion:
    """A finished request: ``tokens`` is prompt + generated — for 'eos' /
    'max_new' exactly the shape one-shot ``generate`` returns for the same
    prompt; for overload/fault outcomes, whatever was produced before the
    lifecycle ended."""
    rid: int
    prompt: np.ndarray
    tokens: np.ndarray
    n_generated: int
    finished: str        # 'eos' | 'max_new' | 'shed' | 'deadline' |
                         # 'refused' | 'pressure'
    submitted_step: int
    finished_step: int
    resumed: int = 0     # preempt/quarantine-survivor re-prefills it took
    error: Optional[str] = None        # diagnostics when finished='refused'


@dataclasses.dataclass
class _Pending:
    """A queued request: fresh (``out`` empty) or awaiting resume after a
    preemption / quarantine survival (``out`` holds the tokens generated
    before eviction)."""
    req: Request
    submitted_step: int
    submit_time: float
    out: List[int] = dataclasses.field(default_factory=list)
    resumed: int = 0


@dataclasses.dataclass
class _Slot:
    """Host-side record of an occupied decode slot."""
    req: Request
    out: List[int]                     # generated tokens so far
    pos: int                           # next cache write position
    key: np.ndarray                    # (2,) uint32 per-request PRNG
    submitted_step: int
    submit_time: float
    resumed: int = 0

    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def prompt(self) -> np.ndarray:
        return self.req.tokens


@partial(jax.jit, static_argnums=(0, 1, 2))
def _generate_step(cfg, mesh, page_size: int, params, lut, pages,
                   page_table, tok, pos, active, temp, keys):
    """Advance every occupied slot one token (single trace per (cfg, mesh)).

    pages: the paged KV pool pytree.  page_table: (B, npr) int32.  tok:
    (B, 1) last tokens.  pos: (B,) per-slot write positions.  active:
    (B,) bool.  temp: (B,) f32.  keys: (B, 2) uint32 per-request PRNG.
    Returns (new pages, (B,) next tokens).
    """
    _engine.TRACE_COUNTS["generate_step"] += 1
    _, decode_step = _engine._raw_serve_fns(cfg)
    with _engine._mesh_ctx(mesh):
        view = paged_view(cfg, pages, page_table)
        logits, new_view = decode_step(params, lut, tok, view, pos)
        subs = jax.vmap(jax.random.fold_in)(keys, pos)
        nxt = _engine.sample_tokens(logits, temp, subs)
        pages = write_token(cfg, page_size, pages, new_view, page_table,
                            pos, active)
    return pages, nxt


class Engine:
    """Continuous-batching serve engine over a paged KV pool.

    ctx: ``ServeContext`` (cfg, mesh, lut).  params: served weights (the
    ``ServeState.params`` pytree).  n_slots × max_len sizes the decode
    pool (max_len rounds up to a page multiple — read it back from
    ``engine.pool.max_len``); ``n_pages`` overcommits the pool when
    smaller than ``n_slots * pages_per_slot`` (free slot ≠ free pages —
    the preemption regime).  ``guard`` hooks every jitted call:
    ``guard(call, kind)`` with ``call(cfg) -> result`` and kind in
    {'prefill', 'decode', 'replay'} — the resilience ladder substitutes
    rung-suffixed configs and retries here (``ResilientEngine.scheduler``).

    Overload knobs: ``max_queue`` bounds the queue (None = unbounded,
    the pre-admission-control behavior); ``shed_policy`` picks who sheds
    on overflow ('reject-new' | 'drop-oldest'); ``request_ttl`` is the
    engine-wide default ``ttl_steps`` for requests that don't carry one.
    Requeues from preemption/quarantine are exempt from ``max_queue`` —
    admitted work is never shed by the bound that admitted it.
    """

    def __init__(self, ctx: ServeContext, params, *, n_slots: int = 4,
                 max_len: int = 64, page_size: int = 8,
                 dtype=jnp.bfloat16, guard=None,
                 max_queue: Optional[int] = None,
                 shed_policy: str = "reject-new",
                 request_ttl: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 governor=None):
        if shed_policy not in SHED_POLICIES:
            raise ValueError(f"shed_policy must be one of {SHED_POLICIES}, "
                             f"got {shed_policy!r}")
        self.ctx = ctx
        self.params = params
        self.pool = PagedKVPool(ctx.cfg, n_slots, max_len,
                                page_size=page_size, dtype=dtype,
                                n_pages=n_pages)
        self.guard = guard or (lambda call, kind: call(self.ctx.cfg))
        self.max_queue = max_queue
        self.shed_policy = shed_policy
        self.request_ttl = request_ttl
        self._queue: collections.deque = collections.deque()
        self._slots: List[Optional[_Slot]] = [None] * n_slots
        self._next_rid = 0
        self.steps = 0
        self.completions: List[Completion] = []
        # Optional serve.governor.MemoryGovernor: runs at the top of every
        # step() (the fence where no jitted call is in flight) and may
        # trim/regrow the residency cache, retire/restore KV pages,
        # preempt in-flight requests, tighten max_queue, or flip the
        # engine into refuse-new-work mode (finished='pressure').
        self.governor = governor
        self.reset_stats()
        if governor is not None:
            governor.attach(self)

    def reset_stats(self) -> None:
        """Zero the lifecycle counters (benchmarks call this after a
        warmup drain so the measured trace starts clean).  Under tiered
        residency, the manager's fetch/hit counters and the module-wide
        ``RESIDENCY_COUNTS`` probe reset too."""
        self.stats = {"admitted": 0, "joined_mid_decode": 0,
                      "occupancy": [], "shed": 0, "expired": 0,
                      "preempted": 0, "quarantined": 0, "resumed": 0,
                      "queue_peak": 0, "pressure_refused": 0,
                      "pressure_preempted": 0}
        mgr = getattr(self.ctx, "residency", None)
        if mgr is not None:
            from repro.serve.residency import RESIDENCY_COUNTS
            RESIDENCY_COUNTS.clear()
            mgr.reset_stats()

    # -- public API ----------------------------------------------------
    def submit(self, request: Request) -> int:
        """Queue a request; returns its rid.  Admission happens on a
        later ``step()`` when a slot (and its pages) free up.  When the
        bounded queue is full, either this submission or the queue head
        sheds per ``shed_policy`` — as a ``Completion(finished='shed')``
        on ``engine.completions``, never a silent drop."""
        toks = np.asarray(request.tokens, np.int32).reshape(-1)
        if toks.size == 0:
            raise ValueError("empty prompt")
        if request.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if toks.size + request.max_new > self.pool.max_len:
            raise ValueError(
                f"prompt ({toks.size}) + max_new ({request.max_new}) "
                f"exceeds pool max_len ({self.pool.max_len})")
        if request.rid is not None:
            rid = request.rid
            live = ({p.req.rid for p in self._queue}
                    | {s.rid for s in self._slots if s is not None})
            if rid in live:
                raise ValueError(
                    f"rid {rid} already in flight (queued or decoding); "
                    "caller-supplied rids must be unique among live "
                    "requests")
            # keep the auto counter ahead of caller-supplied rids so a
            # later submit() without a rid can never collide with one
            self._next_rid = max(self._next_rid, rid + 1)
        else:
            rid = self._next_rid
            self._next_rid += 1
        pending = _Pending(req=dataclasses.replace(request, tokens=toks,
                                                   rid=rid),
                           submitted_step=self.steps,
                           submit_time=time.monotonic())
        if self.governor is not None and self.governor.refusing:
            # rung 4 of the reclaim ladder: the budget fell below
            # min_viable — new work is refused with its own accounted-for
            # reason, never queued behind an engine that cannot grow
            FALLBACK_COUNTS["pressure_refused"] += 1
            self.stats["pressure_refused"] += 1
            self.completions.append(self._completion(
                pending.req.rid, pending.req.tokens, [], "pressure",
                pending.submitted_step))
            return rid
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            if self.shed_policy == "reject-new":
                self._shed(pending)
                return rid
            self._shed(self._queue.popleft())       # drop-oldest
        self._queue.append(pending)
        self.stats["queue_peak"] = max(self.stats["queue_peak"],
                                       len(self._queue))
        return rid

    def step(self) -> List[Completion]:
        """One engine tick: expire → admit → decode one token → retire.
        Returns the completions this tick produced.  When a governor is
        attached it runs first — the step boundary is the only fence
        where no jitted call is in flight, so capacity trims / page
        retirement (which reshape traced arrays) are safe here."""
        if self.governor is not None:
            self.governor.on_step(self)
        done = self._expire()
        done.extend(self._admit())
        occ = [i for i, s in enumerate(self._slots) if s is not None]
        self.stats["occupancy"].append(len(occ))
        if occ:
            done.extend(self._decode_tick())
        self.steps += 1
        self.completions.extend(done)
        return done

    def drain(self, max_steps: int = 100_000) -> List[Completion]:
        """Step until the queue and all slots are empty; returns the
        completions produced while draining."""
        out: List[Completion] = []
        budget = max_steps
        while self._queue or any(s is not None for s in self._slots):
            out.extend(self.step())
            budget -= 1
            if budget <= 0:
                slots = [(i, s.rid, s.pos, len(s.out))
                         for i, s in enumerate(self._slots) if s is not None]
                raise RuntimeError(
                    f"drain did not converge after {max_steps} steps; "
                    f"health={self.health()}; "
                    f"slots (slot, rid, pos, n_out)={slots}; "
                    f"queued rids={[p.req.rid for p in self._queue]}")
        return out

    def health(self) -> dict:
        occ = self.stats["occupancy"]
        out = {
            "steps": self.steps,
            "queued": len(self._queue),
            "queue_peak": self.stats["queue_peak"],
            "occupied": sum(s is not None for s in self._slots),
            "admitted": self.stats["admitted"],
            "joined_mid_decode": self.stats["joined_mid_decode"],
            "occupancy_mean": float(np.mean(occ)) if occ else 0.0,
            "occupancy_max": int(np.max(occ)) if occ else 0,
            "completed": len(self.completions),
            "free_pages": len(self.pool.free_pages),
            "shed": self.stats["shed"],
            "expired": self.stats["expired"],
            "preempted": self.stats["preempted"],
            "quarantined": self.stats["quarantined"],
            "resumed": self.stats["resumed"],
        }
        mgr = getattr(self.ctx, "residency", None)
        if mgr is not None:
            out["residency"] = mgr.snapshot()
        if self.governor is not None:
            out["pressure"] = self.governor.snapshot()
        return out

    def close(self) -> None:
        """Tear down serving-side workers (idempotent).  Today that is
        the residency prefetch thread — nothing else owns it, so an
        engine that was handed a tiered context must stop it or every
        served model leaks a live ``residency-prefetch`` thread."""
        mgr = getattr(self.ctx, "residency", None)
        if mgr is not None:
            mgr.close()

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- overload internals --------------------------------------------
    def _shed(self, p: _Pending) -> None:
        FALLBACK_COUNTS["shed"] += 1
        self.stats["shed"] += 1
        self.completions.append(self._completion(
            p.req.rid, p.req.tokens, p.out, "shed", p.submitted_step,
            resumed=p.resumed))

    def _is_expired(self, ttl_steps, deadline_s, submitted_step,
                    submit_time) -> bool:
        ttl = ttl_steps if ttl_steps is not None else self.request_ttl
        if ttl is not None and self.steps - submitted_step >= ttl:
            return True
        if deadline_s is not None and \
                time.monotonic() - submit_time > deadline_s:
            return True
        return False

    def _expire(self) -> List[Completion]:
        """Retire queued and in-flight requests whose TTL/deadline (from
        submit time) has passed — Completion(finished='deadline') with
        whatever tokens exist, FALLBACK_COUNTS['expired'] per request."""
        done: List[Completion] = []
        if self._queue:
            keep: collections.deque = collections.deque()
            while self._queue:
                p = self._queue.popleft()
                if self._is_expired(p.req.ttl_steps, p.req.deadline_s,
                                    p.submitted_step, p.submit_time):
                    FALLBACK_COUNTS["expired"] += 1
                    self.stats["expired"] += 1
                    done.append(self._completion(
                        p.req.rid, p.req.tokens, p.out, "deadline",
                        p.submitted_step, resumed=p.resumed))
                else:
                    keep.append(p)
            self._queue = keep
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            if self._is_expired(s.req.ttl_steps, s.req.deadline_s,
                                s.submitted_step, s.submit_time):
                FALLBACK_COUNTS["expired"] += 1
                self.stats["expired"] += 1
                done.append(self._completion(
                    s.rid, s.prompt, s.out, "deadline", s.submitted_step,
                    resumed=s.resumed))
                self.pool.free(i)
                self._slots[i] = None
        return done

    def _preempt_for(self, head: _Pending) -> bool:
        """Evict the lowest-priority (tie: youngest) in-flight request to
        reclaim pages for ``head`` — only if the victim ranks *strictly*
        below it (equal-priority traffic must not livelock-swap)."""
        occ = [(s.req.priority, -s.submitted_step, i)
               for i, s in enumerate(self._slots) if s is not None]
        if not occ:
            return False
        _, _, i = min(occ)
        victim = self._slots[i]
        if victim.req.priority >= head.req.priority:
            return False
        FALLBACK_COUNTS["preempt"] += 1
        self.stats["preempted"] += 1
        # requeue right behind the head that displaced it, carrying its
        # generated tokens; it resumes via re-prefill when pages free up
        self._queue.insert(1, _Pending(
            req=victim.req, submitted_step=victim.submitted_step,
            submit_time=victim.submit_time, out=list(victim.out),
            resumed=victim.resumed + 1))
        self.pool.free(i)
        self._slots[i] = None
        return True

    def preempt_lowest(self) -> bool:
        """Evict the lowest-priority (tie: youngest) in-flight request to
        give its pages back under *memory pressure* (governor rung 2).
        Unlike ``_preempt_for`` there is no displacing head, so no
        priority precondition — the pool itself must shrink and someone
        has to yield.  The victim requeues at the front with its tokens
        and resumes bitwise-equal via the re-prefill path once pages
        exist again."""
        occ = [(s.req.priority, -s.submitted_step, i)
               for i, s in enumerate(self._slots) if s is not None]
        if not occ:
            return False
        _, _, i = min(occ)
        victim = self._slots[i]
        FALLBACK_COUNTS["pressure_preempt"] += 1
        self.stats["preempted"] += 1
        self.stats["pressure_preempted"] += 1
        self._queue.appendleft(_Pending(
            req=victim.req, submitted_step=victim.submitted_step,
            submit_time=victim.submit_time, out=list(victim.out),
            resumed=victim.resumed + 1))
        self.pool.free(i)
        self._slots[i] = None
        return True

    # -- admission -----------------------------------------------------
    def _prefill(self, toks: np.ndarray):
        """Jitted prefill of a 1-D token sequence into a fresh
        ``max_len``-long cache fragment — the same closure and cache shape
        one-shot ``generate`` uses, so the fragment is bitwise what
        generate's cache would hold."""
        toks = jnp.asarray(np.asarray(toks, np.int32)[None, :])
        caches = LM.init_caches(self.ctx.cfg, 1, self.pool.max_len)

        def call(cfg):
            prefill, _ = _engine.make_serve_fns(
                ctx=self.ctx.with_cfg(cfg))
            return prefill(self.params, self.ctx.lut,
                           {"tokens": toks, "embeds": None}, caches)

        logits, frag = self.guard(call, "prefill")
        tok0 = int(np.asarray(_engine.sample_tokens(logits, 0.0))[0])
        return tok0, frag

    def _admit(self) -> List[Completion]:
        """Move queued requests into free slots (prefill → insert).

        Fresh requests prefill their prompt; resumes (preempted /
        quarantine survivors) prefill prompt + out[:-1] so the cache holds
        exactly what the uninterrupted run's cache held, then continue
        from their last emitted token at the same absolute position.  A
        request whose prefill *itself* faults past the guard is refused
        alone (``finished='refused'``) — one poisoned prompt cannot stall
        the queue behind it."""
        done: List[Completion] = []
        while self._queue:
            free = [i for i, s in enumerate(self._slots) if s is None]
            if not free:
                break
            if not self.pool.can_alloc():
                if not self._preempt_for(self._queue[0]):
                    break
                free = [i for i, s in enumerate(self._slots) if s is None]
            p = self._queue.popleft()
            req = p.req
            resume = bool(p.out)
            toks = (np.concatenate([req.tokens,
                                    np.asarray(p.out[:-1], np.int32)])
                    if resume else req.tokens)
            try:
                tok0, frag = self._prefill(toks)
            except _FAULTS as e:
                FALLBACK_COUNTS["quarantine"] += 1
                self.stats["quarantined"] += 1
                done.append(self._completion(
                    req.rid, req.tokens, p.out, "refused", p.submitted_step,
                    resumed=p.resumed, error=repr(e)))
                continue
            self.stats["admitted"] += 1
            if resume:
                self.stats["resumed"] += 1
            if any(s is not None for s in self._slots):
                self.stats["joined_mid_decode"] += 1
            if not resume:
                if req.max_new == 1 or (req.eos_id is not None
                                        and tok0 == req.eos_id):
                    done.append(self._completion(
                        req.rid, req.tokens, [tok0],
                        "eos" if (req.eos_id is not None
                                  and tok0 == req.eos_id)
                        else "max_new", p.submitted_step))
                    continue
                out = [tok0]
            else:
                out = list(p.out)      # resume: discard the probe token
            slot = free[0]
            try:
                self.pool.alloc(slot)
            except PoolExhausted:
                # pressure surfaced at the alloc seam itself (injected
                # fault, or raced reclaim): requeue at the head and retry
                # next tick — prefill is pure, so nothing is lost
                self._queue.appendleft(p)
                break
            self.pool.insert(frag, slot)
            self._slots[slot] = _Slot(
                req=req, out=out, pos=len(req.tokens) + len(out) - 1,
                key=np.asarray(jax.random.PRNGKey(req.seed), np.uint32),
                submitted_step=p.submitted_step, submit_time=p.submit_time,
                resumed=p.resumed)
        return done

    # -- decode --------------------------------------------------------
    def _decode_tick(self) -> List[Completion]:
        b = self.pool.n_slots
        tok = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        temp = np.zeros((b,), np.float32)
        keys = np.zeros((b, 2), np.uint32)
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            tok[i, 0] = s.out[-1]
            pos[i] = s.pos
            active[i] = True
            temp[i] = s.req.temperature
            keys[i] = s.key
        pt = jnp.asarray(self.pool.page_table)

        def call_with(mask):
            mgr = getattr(self.ctx, "residency", None)

            def call(cfg):
                if mgr is not None:
                    # tiered residency: run the routed twin of the step
                    # under the fetch/replay protocol.  Only active
                    # slots' routing drives fetches; the launch is pure
                    # (pages are returned, not committed), so replays
                    # are safe and parity holds per decode tick.
                    from repro.serve import residency as _res
                    mgr.check_params(self.params)

                    def launch(dp):
                        pages_, nxt_, routing = _res._tiered_generate_step(
                            cfg, self.ctx.mesh, self.pool.page_size, dp,
                            self.ctx.lut, self.pool.pages, pt,
                            jnp.asarray(tok), jnp.asarray(pos),
                            jnp.asarray(mask), jnp.asarray(temp),
                            jnp.asarray(keys))
                        return (pages_, nxt_), routing

                    return mgr.run(launch, active=mask)
                return _generate_step(
                    cfg, self.ctx.mesh, self.pool.page_size, self.params,
                    self.ctx.lut, self.pool.pages, pt, jnp.asarray(tok),
                    jnp.asarray(pos), jnp.asarray(mask), jnp.asarray(temp),
                    jnp.asarray(keys))
            return call

        try:
            pages, nxt = self.guard(call_with(active), "decode")
        except _FAULTS as e:
            return self._quarantine(active, call_with, e)
        self.pool.pages = pages
        nxt = np.asarray(nxt)

        done: List[Completion] = []
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            t = int(nxt[i])
            s.out.append(t)
            s.pos += 1
            if len(s.out) >= s.req.max_new or (s.req.eos_id is not None
                                               and t == s.req.eos_id):
                reason = ("eos" if s.req.eos_id is not None
                          and t == s.req.eos_id else "max_new")
                done.append(self._completion(s.rid, s.prompt, s.out,
                                             reason, s.submitted_step,
                                             resumed=s.resumed))
                self.pool.free(i)
                self._slots[i] = None
        return done

    def _quarantine(self, active, call_with, exc) -> List[Completion]:
        """Bisect the active slots to isolate the poisoned request(s).

        Replays masked sub-batches through the already-jitted step (the
        mask is a traced value — no retrace); a subset that faults is
        split, a subset that succeeds is vindicated wholesale.  Culprits
        are refused (``finished='refused'``), survivors requeued at the
        front with their accumulated tokens for a resume re-prefill.  If
        no individual culprit reproduces the fault (a cross-request
        interaction or a genuinely global fault), the original error
        re-raises — refusing everyone blindly would be worse than loud
        failure."""
        occupied = [i for i in range(len(self._slots)) if active[i]]

        def faults(subset) -> bool:
            mask = np.zeros_like(active)
            mask[list(subset)] = True
            try:
                self.guard(call_with(mask), "replay")  # outputs discarded
                return False
            except _FAULTS:
                return True

        def bisect(group, known_faulty) -> List[int]:
            if not known_faulty and not faults(group):
                return []
            if len(group) == 1:
                return list(group)
            mid = len(group) // 2
            return bisect(group[:mid], False) + bisect(group[mid:], False)

        culprits = set(bisect(occupied, True))
        if not culprits:
            raise exc
        done: List[Completion] = []
        survivors: List[_Pending] = []
        for i in occupied:
            s = self._slots[i]
            if i in culprits:
                FALLBACK_COUNTS["quarantine"] += 1
                self.stats["quarantined"] += 1
                done.append(self._completion(
                    s.rid, s.prompt, s.out, "refused", s.submitted_step,
                    resumed=s.resumed, error=repr(exc)))
            else:
                # the faulted tick never committed pages, but post-fault
                # device state is not worth trusting: resume from host
                # tokens via a fresh prefill
                survivors.append(_Pending(
                    req=s.req, submitted_step=s.submitted_step,
                    submit_time=s.submit_time, out=list(s.out),
                    resumed=s.resumed + 1))
            self.pool.free(i)
            self._slots[i] = None
        self._queue.extendleft(reversed(survivors))
        return done

    def _completion(self, rid, prompt, out, reason, submitted, *,
                    resumed: int = 0, error: Optional[str] = None
                    ) -> Completion:
        return Completion(
            rid=rid, prompt=np.asarray(prompt),
            tokens=np.concatenate([np.asarray(prompt, np.int32),
                                   np.asarray(out, np.int32)]),
            n_generated=len(out), finished=reason,
            submitted_step=submitted, finished_step=self.steps,
            resumed=resumed, error=error)
