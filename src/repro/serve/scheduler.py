"""Continuous-batching scheduler — the request-level serving API.

The one-shot ``engine.generate`` runs a fixed batch through a single
``lax.scan``: no request can join or leave a running decode loop, so real
traffic (staggered arrivals, varied lengths) serializes.  This module is
the serving front door built on the prefill→insert→generate-step split:

  * ``Request``/``Completion`` — the public dataclasses.  A request is a
    prompt plus decode budget (``max_new``), optional ``eos_id``, and
    sampling controls; a completion carries the full ``generate``-shaped
    token sequence plus lifecycle metadata (submit/finish step, reason).
  * ``Engine.submit(request)`` — queue a request (returns its rid).
  * ``Engine.step()`` — one engine tick: admit queued requests into free
    decode slots (jitted prefill into a cache *fragment*, then
    ``kv_cache.insert_fragment`` into the slot's pages), advance every
    occupied slot one token with the jitted ``_generate_step``, and
    retire slots that hit EOS or their ``max_new`` budget — freeing their
    pages for the next queued request.  Returns the requests completed by
    this tick.
  * ``Engine.drain()`` — step until queue and slots are empty.

``_generate_step`` is jitted once per (cfg, mesh): the paged view, the
per-slot position vector, the active mask, and the page table are all
*traced* values, so admissions and completions never retrace.  Each tick
advances all occupied slots with per-slot position/length masks — vacant
slots compute garbage that is masked out of storage by the
``write_token`` OOB-drop scatter.

Parity invariant (the acceptance bar): a request served through the
engine yields tokens bitwise-equal to ``engine.generate`` of the same
prompt with ``max_len=engine.pool.max_len``.  The ingredients: prefill
uses the *same* jitted closure over the same cache shape; masked cache
entries (-1e30 → exp underflows to exactly 0.0) contribute nothing to the
softmax sums regardless of what stale pages hold; and both paths sample
through ``engine.sample_tokens``.  MoE configs additionally need the
dropless regime (``capacity_factor >= n_experts / top_k``) — expert
capacity depends on batch size, so capacity *drops* may differ between
batch shapes.

``ResilientEngine.scheduler()`` wraps every jitted step in the
retry/deadline/degradation ladder via the ``guard`` hook — see
serve/resilience.py and docs/serving.md.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from functools import partial
from typing import Any, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import lm as LM
from repro.serve import engine as _engine
from repro.serve.context import ServeContext
from repro.serve.kv_cache import PagedKVPool, paged_view, write_token


@dataclasses.dataclass
class Request:
    """One generation request.

    tokens: (T,) int prompt.  max_new: decode budget, generated tokens
    including the one the prefill emits.  eos_id: stop token (the emitted
    sequence includes it).  temperature/seed: sampling controls — the
    per-request PRNG is folded with the absolute position each step, so
    tokens are reproducible regardless of slot placement or co-tenants.
    """
    tokens: Any
    max_new: int = 16
    eos_id: Optional[int] = None
    temperature: float = 0.0
    seed: int = 0
    rid: Optional[int] = None          # assigned by submit() when None


@dataclasses.dataclass
class Completion:
    """A finished request: ``tokens`` is prompt + generated, exactly the
    shape one-shot ``generate`` returns for the same prompt."""
    rid: int
    prompt: np.ndarray
    tokens: np.ndarray
    n_generated: int
    finished: str                      # 'eos' | 'max_new'
    submitted_step: int
    finished_step: int


@dataclasses.dataclass
class _Slot:
    """Host-side record of an occupied decode slot."""
    rid: int
    prompt: np.ndarray
    out: List[int]                     # generated tokens so far
    pos: int                           # next cache write position
    max_new: int
    eos_id: Optional[int]
    temperature: float
    key: np.ndarray                    # (2,) uint32 per-request PRNG
    submitted_step: int


@partial(jax.jit, static_argnums=(0, 1, 2))
def _generate_step(cfg, mesh, page_size: int, params, lut, pages,
                   page_table, tok, pos, active, temp, keys):
    """Advance every occupied slot one token (single trace per (cfg, mesh)).

    pages: the paged KV pool pytree.  page_table: (B, npr) int32.  tok:
    (B, 1) last tokens.  pos: (B,) per-slot write positions.  active:
    (B,) bool.  temp: (B,) f32.  keys: (B, 2) uint32 per-request PRNG.
    Returns (new pages, (B,) next tokens).
    """
    _engine.TRACE_COUNTS["generate_step"] += 1
    _, decode_step = _engine._raw_serve_fns(cfg)
    with _engine._mesh_ctx(mesh):
        view = paged_view(cfg, pages, page_table)
        logits, new_view = decode_step(params, lut, tok, view, pos)
        subs = jax.vmap(jax.random.fold_in)(keys, pos)
        nxt = _engine.sample_tokens(logits, temp, subs)
        pages = write_token(cfg, page_size, pages, new_view, page_table,
                            pos, active)
    return pages, nxt


class Engine:
    """Continuous-batching serve engine over a paged KV pool.

    ctx: ``ServeContext`` (cfg, mesh, lut).  params: served weights (the
    ``ServeState.params`` pytree).  n_slots × max_len sizes the decode
    pool (max_len rounds up to a page multiple — read it back from
    ``engine.pool.max_len``).  ``guard`` hooks every jitted call:
    ``guard(call, kind)`` with ``call(cfg) -> result`` and kind in
    {'prefill', 'decode'} — the resilience ladder substitutes
    rung-suffixed configs and retries here (``ResilientEngine.scheduler``).
    """

    def __init__(self, ctx: ServeContext, params, *, n_slots: int = 4,
                 max_len: int = 64, page_size: int = 8,
                 dtype=jnp.bfloat16, guard=None):
        self.ctx = ctx
        self.params = params
        self.pool = PagedKVPool(ctx.cfg, n_slots, max_len,
                                page_size=page_size, dtype=dtype)
        self.guard = guard or (lambda call, kind: call(self.ctx.cfg))
        self._queue: collections.deque = collections.deque()
        self._slots: List[Optional[_Slot]] = [None] * n_slots
        self._rid = itertools.count()
        self.steps = 0
        self.completions: List[Completion] = []
        self.stats = {"admitted": 0, "joined_mid_decode": 0,
                      "occupancy": []}

    # -- public API ----------------------------------------------------
    def submit(self, request: Request) -> int:
        """Queue a request; returns its rid.  Admission happens on the
        next ``step()`` when a slot (and its pages) free up."""
        toks = np.asarray(request.tokens, np.int32).reshape(-1)
        if toks.size == 0:
            raise ValueError("empty prompt")
        if request.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if toks.size + request.max_new > self.pool.max_len:
            raise ValueError(
                f"prompt ({toks.size}) + max_new ({request.max_new}) "
                f"exceeds pool max_len ({self.pool.max_len})")
        rid = request.rid if request.rid is not None else next(self._rid)
        self._queue.append(dataclasses.replace(request, tokens=toks,
                                               rid=rid))
        return rid

    def step(self) -> List[Completion]:
        """One engine tick: admit → decode one token → retire.  Returns
        the completions this tick produced."""
        done = self._admit()
        occ = [i for i, s in enumerate(self._slots) if s is not None]
        self.stats["occupancy"].append(len(occ))
        if occ:
            done.extend(self._decode_tick())
        self.steps += 1
        self.completions.extend(done)
        return done

    def drain(self, max_steps: int = 100_000) -> List[Completion]:
        """Step until the queue and all slots are empty; returns the
        completions produced while draining."""
        out: List[Completion] = []
        while self._queue or any(s is not None for s in self._slots):
            out.extend(self.step())
            max_steps -= 1
            if max_steps <= 0:
                raise RuntimeError("drain did not converge")
        return out

    def health(self) -> dict:
        occ = self.stats["occupancy"]
        return {
            "steps": self.steps,
            "queued": len(self._queue),
            "occupied": sum(s is not None for s in self._slots),
            "admitted": self.stats["admitted"],
            "joined_mid_decode": self.stats["joined_mid_decode"],
            "occupancy_mean": float(np.mean(occ)) if occ else 0.0,
            "occupancy_max": int(np.max(occ)) if occ else 0,
            "completed": len(self.completions),
            "free_pages": len(self.pool.free_pages),
        }

    # -- internals -----------------------------------------------------
    def _prefill(self, req: Request):
        """Jitted prefill into a fresh ``max_len``-long cache fragment —
        the same closure and cache shape one-shot ``generate`` uses, so
        the fragment is bitwise what generate's cache would hold."""
        toks = jnp.asarray(req.tokens[None, :])
        caches = LM.init_caches(self.ctx.cfg, 1, self.pool.max_len)

        def call(cfg):
            prefill, _ = _engine.make_serve_fns(
                ctx=self.ctx.with_cfg(cfg))
            return prefill(self.params, self.ctx.lut,
                           {"tokens": toks, "embeds": None}, caches)

        logits, frag = self.guard(call, "prefill")
        tok0 = int(np.asarray(_engine.sample_tokens(logits, 0.0))[0])
        return tok0, frag

    def _admit(self) -> List[Completion]:
        """Move queued requests into free slots (prefill → insert)."""
        done: List[Completion] = []
        while self._queue:
            free = [i for i, s in enumerate(self._slots) if s is None]
            if not free:
                break
            req = self._queue.popleft()
            tok0, frag = self._prefill(req)
            self.stats["admitted"] += 1
            if any(s is not None for s in self._slots):
                self.stats["joined_mid_decode"] += 1
            if req.max_new == 1 or (req.eos_id is not None
                                    and tok0 == req.eos_id):
                done.append(self._completion(
                    req.rid, req.tokens, [tok0],
                    "eos" if (req.eos_id is not None and tok0 == req.eos_id)
                    else "max_new", self.steps))
                continue
            slot = free[0]
            self.pool.alloc(slot)
            self.pool.insert(frag, slot)
            self._slots[slot] = _Slot(
                rid=req.rid, prompt=req.tokens, out=[tok0],
                pos=len(req.tokens), max_new=req.max_new,
                eos_id=req.eos_id, temperature=req.temperature,
                key=np.asarray(jax.random.PRNGKey(req.seed), np.uint32),
                submitted_step=self.steps)
        return done

    def _decode_tick(self) -> List[Completion]:
        b = self.pool.n_slots
        tok = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        temp = np.zeros((b,), np.float32)
        keys = np.zeros((b, 2), np.uint32)
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            tok[i, 0] = s.out[-1]
            pos[i] = s.pos
            active[i] = True
            temp[i] = s.temperature
            keys[i] = s.key
        pt = jnp.asarray(self.pool.page_table)

        def call(cfg):
            return _generate_step(
                cfg, self.ctx.mesh, self.pool.page_size, self.params,
                self.ctx.lut, self.pool.pages, pt, jnp.asarray(tok),
                jnp.asarray(pos), jnp.asarray(active), jnp.asarray(temp),
                jnp.asarray(keys))

        pages, nxt = self.guard(call, "decode")
        self.pool.pages = pages
        nxt = np.asarray(nxt)

        done: List[Completion] = []
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            t = int(nxt[i])
            s.out.append(t)
            s.pos += 1
            if len(s.out) >= s.max_new or (s.eos_id is not None
                                           and t == s.eos_id):
                reason = ("eos" if s.eos_id is not None and t == s.eos_id
                          else "max_new")
                done.append(self._completion(s.rid, s.prompt, s.out,
                                             reason, s.submitted_step))
                self.pool.free(i)
                self._slots[i] = None
        return done

    def _completion(self, rid, prompt, out, reason, submitted) -> Completion:
        return Completion(
            rid=rid, prompt=np.asarray(prompt),
            tokens=np.concatenate([np.asarray(prompt, np.int32),
                                   np.asarray(out, np.int32)]),
            n_generated=len(out), finished=reason,
            submitted_step=submitted, finished_step=self.steps)
