"""Paged KV cache — the memory substrate of the continuous-batching engine.

A fixed pool of KV *pages* backs a fixed set of decode *slots*.  Each slot
owns ``pages_per_slot`` pages, assembled through a per-slot page table
into a contiguous-looking cache view of length ``max_len``:

  * ``PagedKVPool`` — host-side allocator.  The device state is one cache
    pytree shaped exactly like ``LM.init_caches(cfg, n_pages, page_size)``
    (batch axis = page id, time axis = in-page offset), so every cache
    layout the model zoo produces — stacked ``(L, B, T, ...)`` block
    leaves, per-layer list leaves, MLA latent planes, int8-KV scale
    planes — pages uniformly.  Per-leaf (batch, time) axes come from
    ``LM.cache_batch_time_axes`` (families without a time axis — ssm /
    hybrid recurrent state — are rejected there).
  * ``paged_view(cfg, pages, page_table)`` — gather the pool into the
    per-slot ``(n_slots, max_len, ...)`` view the model's decode step
    consumes.  Pure and traceable: it runs *inside* the jitted
    ``generate_step``, and page-table contents are traced values, so
    admissions never retrace.
  * ``write_token(...)`` — scatter the cache entries a decode step wrote
    at each slot's position back into the pool.  Inactive slots write to
    an out-of-range page id and are dropped (``mode='drop'``), so a freed
    slot can never clobber pages that now belong to another request.
  * ``insert_fragment(...)`` — copy a prefill fragment (a batch-1,
    ``max_len``-long cache) over the slot's whole page set.  Overwriting
    the full region — zero tail included — is what makes page reuse safe:
    a new tenant never sees the previous tenant's KV, and the view is
    bitwise-identical to the zero-initialized cache a one-shot
    ``generate`` of the same prompt would hold.

Pages are fungible across slots: ``alloc`` hands out whatever is on the
free list (LIFO, so reuse is immediate and the stale-KV tests actually
exercise cross-request reuse), ``free`` returns a completed slot's pages.

The pool can be *overcommitted*: ``n_pages`` may be smaller than
``n_slots * pages_per_slot``, in which case a free slot is not a
guarantee of free pages — ``alloc`` raises ``PoolExhausted`` (and
``can_alloc`` reports False) when the free list cannot back another
slot.  The scheduler turns that pressure into preemption/shedding
instead of letting admits fail (see serve/scheduler.py).

The pool is also *elastic at runtime* (the memory-pressure regime,
serve/governor.py): ``retire_pages`` removes free pages from
circulation — highest ids first, so a contiguous retired tail can be
physically sliced off the device arrays and its HBM actually released —
and ``restore_pages`` brings them back (re-growing the device arrays
when the retired set is exhausted).  Both change the pool pytree's
shapes, so the jitted ``generate_step``/``insert_fragment`` re-trace on
the next call; the governor fences these behind step boundaries and
amortizes them with hysteresis.  Live pages are never moved: an
occupied slot's page ids stay valid across any retire/restore sequence,
which is what keeps pressured outputs bitwise-equal to unpressured ones.
"""
from __future__ import annotations

import functools
from typing import Any, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import lm as LM


class PoolError(RuntimeError):
    """Slot-ownership invariant violated (double alloc, bad slot id).

    A real exception — not an ``assert`` — because the ownership invariant
    guards page aliasing between live requests and must hold under
    ``python -O`` too."""


class PoolExhausted(PoolError):
    """The free list cannot back another slot's ``pages_per_slot`` pages.

    Raised by ``alloc`` under page pressure (overcommitted pools, or
    injected via ``FaultInjector.alloc_failure``); the scheduler's
    admission path catches it and preempts/queues instead of crashing."""


def _is_axes(x) -> bool:
    return isinstance(x, tuple)


@functools.lru_cache(maxsize=None)
def _axes_leaves(cfg) -> tuple:
    """Flattened per-leaf (batch_axis, time_axis), cached per config."""
    tree = LM.cache_batch_time_axes(cfg)
    return tuple(jax.tree_util.tree_leaves(tree, is_leaf=_is_axes))


def paged_view(cfg, pages, page_table):
    """Assemble per-slot contiguous cache views from the page pool.

    ``page_table``: (n_slots, pages_per_slot) int32 page ids (traced ok).
    Returns a cache pytree shaped like ``init_caches(cfg, n_slots,
    pages_per_slot * page_size)`` — what the decode step consumes.
    """
    leaves, treedef = jax.tree_util.tree_flatten(pages)
    axes = _axes_leaves(cfg)
    flat = page_table.reshape(-1)
    n_slots = page_table.shape[0]
    out = []
    for leaf, (ba, ta) in zip(leaves, axes):
        v = jnp.take(leaf, flat, axis=ba)
        out.append(v.reshape(v.shape[:ba] + (n_slots, -1)
                             + v.shape[ta + 1:]))
    return treedef.unflatten(out)


def write_token(cfg, page_size: int, pages, view, page_table, pos, active):
    """Scatter each slot's cache entry at ``pos`` from ``view`` into pages.

    ``view`` is the (functionally) updated cache the decode step returned —
    only the entry at each slot's own position is new; everything else
    already lives in the pool.  ``active`` (n_slots,) bool: inactive slots
    get an out-of-range page id and drop, so garbage rows from vacant
    slots never reach storage.
    """
    leaves, treedef = jax.tree_util.tree_flatten(pages)
    vleaves = jax.tree_util.tree_leaves(view)
    axes = _axes_leaves(cfg)
    n_pages = leaves[0].shape[axes[0][0]]
    page_of = jnp.take_along_axis(
        page_table, (pos // page_size)[:, None], axis=1)[:, 0]
    page = jnp.where(active, page_of, n_pages)            # OOB when inactive
    off = pos % page_size
    out = []
    for leaf, vleaf, (ba, ta) in zip(leaves, vleaves, axes):
        idx_shape = [1] * vleaf.ndim
        idx_shape[ba] = pos.shape[0]
        idx = pos.reshape(idx_shape)
        ent = jnp.take_along_axis(vleaf, idx, axis=ta)
        ent = jnp.squeeze(ent, axis=ta)
        sel = (slice(None),) * ba + (page, off)
        out.append(leaf.at[sel].set(ent, mode="drop"))
    return treedef.unflatten(out)


@functools.partial(jax.jit, static_argnums=(0, 1))
def insert_fragment(cfg, page_size: int, pages, fragment, page_row):
    """Copy a prefill fragment over one slot's page set.

    ``fragment``: cache pytree with batch 1 and time ``pages_per_slot *
    page_size`` (the prefill's working cache).  ``page_row``: (pages_per_
    slot,) page ids owned by the slot.  The whole region is overwritten —
    the fragment's zero tail included — so the previous tenant's KV can
    never leak into the new request's view.
    """
    leaves, treedef = jax.tree_util.tree_flatten(pages)
    fleaves = jax.tree_util.tree_leaves(fragment)
    axes = _axes_leaves(cfg)
    npr = page_row.shape[0]
    out = []
    for leaf, fleaf, (ba, ta) in zip(leaves, fleaves, axes):
        resh = fleaf.reshape(fleaf.shape[:ba] + (npr, page_size)
                             + fleaf.shape[ta + 1:])
        sel = (slice(None),) * ba + (page_row,)
        out.append(leaf.at[sel].set(resh.astype(leaf.dtype)))
    return treedef.unflatten(out)


class PagedKVPool:
    """Host-side page allocator over a device-resident cache pool.

    ``pages`` is the functional device state (replaced wholesale by
    ``insert``/scheduler writes); the page table and free list are plain
    host state — admission decisions never touch the device.
    """

    def __init__(self, cfg, n_slots: int, max_len: int, *,
                 page_size: int = 8, dtype=jnp.bfloat16,
                 n_pages: int | None = None):
        _axes_leaves(cfg)             # fail fast on unsupported families
        self.cfg = cfg
        self.n_slots = n_slots
        self.page_size = page_size
        self.pages_per_slot = -(-max_len // page_size)
        self.max_len = self.pages_per_slot * page_size
        # n_pages < n_slots * pages_per_slot overcommits the pool: slots
        # can be free while pages are not (the page-pressure regime).
        self.n_pages = (n_slots * self.pages_per_slot if n_pages is None
                        else n_pages)
        if self.n_pages < self.pages_per_slot:
            raise ValueError(
                f"n_pages ({self.n_pages}) cannot back even one slot "
                f"({self.pages_per_slot} pages/slot)")
        self.pages = LM.init_caches(cfg, self.n_pages, page_size, dtype)
        self.page_table = np.zeros((n_slots, self.pages_per_slot), np.int32)
        self.free_pages: List[int] = list(range(self.n_pages))
        self._owned = [False] * n_slots
        # Runtime elasticity (serve/governor.py): retired pages are out of
        # circulation but may still be physically present until the tail
        # they sit in frees up and can be sliced off.
        self.retired: set = set()
        self._dtype = dtype

    @property
    def n_pages_usable(self) -> int:
        """Pages in circulation: physically present minus retired."""
        return self.n_pages - len(self.retired)

    def page_nbytes(self) -> int:
        """Device bytes of one page across every cache leaf."""
        return self.device_bytes() // max(self.n_pages, 1)

    def device_bytes(self) -> int:
        """Physical device bytes of the page pool right now — shrinks when
        a retired tail is released, regrows with ``restore_pages``."""
        return sum(int(l.nbytes) for l in jax.tree_util.tree_leaves(
            self.pages) if hasattr(l, "nbytes"))

    def can_alloc(self) -> bool:
        """Whether the free list can back another slot right now."""
        return len(self.free_pages) >= self.pages_per_slot

    # -- runtime shrink / regrow (memory-pressure governor) ------------
    def retire_pages(self, n: int) -> int:
        """Take up to ``n`` *free* pages out of circulation; returns how
        many were actually retired.  Highest ids go first so the retired
        set accumulates at the pool's tail, and any contiguous all-retired
        tail is physically sliced off the device arrays (real HBM given
        back).  Never touches an owned page — live requests keep their KV
        bitwise-intact — so under pressure the caller preempts requests
        (freeing their pages) and retires again."""
        take = sorted(self.free_pages, reverse=True)[:max(0, int(n))]
        for p in take:
            self.free_pages.remove(p)
            self.retired.add(p)
        self._release_tail()
        return len(take)

    def restore_pages(self, n: int) -> int:
        """Return up to ``n`` pages to circulation (the regrow rung).
        Retired-but-still-present pages come back first; past those, the
        device arrays grow fresh zero pages (new ids at the tail).
        Returns the number restored."""
        n = max(0, int(n))
        back = sorted(self.retired)[:n]
        for p in back:
            self.retired.discard(p)
            self.free_pages.append(p)
        grow = n - len(back)
        if grow > 0:
            self._grow_pages(grow)
        return n

    def _release_tail(self) -> None:
        """Physically drop the contiguous retired tail, if any.  Changes
        leaf shapes → next jitted step re-traces (callers fence this)."""
        new_n = self.n_pages
        while (new_n - 1) in self.retired:
            new_n -= 1
        if new_n == self.n_pages:
            return
        for p in range(new_n, self.n_pages):
            self.retired.discard(p)
        leaves, treedef = jax.tree_util.tree_flatten(self.pages)
        axes = _axes_leaves(self.cfg)
        out = []
        for leaf, (ba, _) in zip(leaves, axes):
            out.append(leaf[(slice(None),) * ba + (slice(0, new_n),)])
        self.pages = treedef.unflatten(out)
        self.n_pages = new_n

    def _grow_pages(self, extra: int) -> None:
        leaves, treedef = jax.tree_util.tree_flatten(self.pages)
        axes = _axes_leaves(self.cfg)
        out = []
        for leaf, (ba, _) in zip(leaves, axes):
            shape = list(leaf.shape)
            shape[ba] = extra
            out.append(jnp.concatenate(
                [leaf, jnp.zeros(shape, leaf.dtype)], axis=ba))
        self.pages = treedef.unflatten(out)
        self.free_pages.extend(range(self.n_pages, self.n_pages + extra))
        self.n_pages += extra

    def alloc(self, slot: int) -> np.ndarray:
        """Claim ``pages_per_slot`` pages for ``slot`` (LIFO reuse)."""
        if self._owned[slot]:
            raise PoolError(f"slot {slot} already owns pages")
        if len(self.free_pages) < self.pages_per_slot:
            raise PoolExhausted(
                f"page pool exhausted: {len(self.free_pages)} free of "
                f"{self.n_pages}, need {self.pages_per_slot}")
        row = [self.free_pages.pop() for _ in range(self.pages_per_slot)]
        self.page_table[slot] = row
        self._owned[slot] = True
        return self.page_table[slot]

    def free(self, slot: int) -> None:
        """Return ``slot``'s pages to the free list.  Freeing a slot that
        owns nothing is a safe no-op: the retire, quarantine, and preempt
        paths may each try to release the same slot."""
        if self._owned[slot]:
            self.free_pages.extend(int(p) for p in self.page_table[slot])
            self._owned[slot] = False
            # point the vacant row at page 0: after a retired tail is
            # physically released, a stale id could land out of range in
            # the paged_view gather — always-in-bounds beats fill garbage
            self.page_table[slot] = 0

    def insert(self, fragment, slot: int) -> None:
        """Write a prefill fragment into ``slot``'s pages (jitted scatter)."""
        row = jnp.asarray(self.page_table[slot])
        self.pages = insert_fragment(self.cfg, self.page_size, self.pages,
                                     fragment, row)
