"""Decoder-only LM assembly — dense / MoE / SSM / hybrid / VLM families.

Layers are stacked (leading L dim on every leaf) and run under ``lax.scan``
so HLO stays O(1) in depth — required to compile the 126-layer / 61-layer
giants in the dry-run container (DESIGN.md §6).  Heterogeneous stacks
(deepseek's first dense layer, zamba2's shared attention insertions) unroll
the exceptional blocks and scan the homogeneous majority.

All entry points take ``lut`` (the shared dictionary LUT) so compressed
weights decode in-graph — the paper's decompress-on-demand per layer.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.sharding.partition import constrain_batch

from . import layers as L
from . import ssm as S

Params = Any


# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------

def _init_block(key, cfg, kind: str, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    if kind == "dense":
        return {
            "attn_norm": jnp.ones((d,), dtype),
            "attn": L.init_attention(ks[0], cfg, dtype),
            "mlp_norm": jnp.ones((d,), dtype),
            "mlp": L.init_mlp(ks[1], d, cfg.d_ff, dtype),
        }
    if kind == "moe":
        attn = (L.init_mla(ks[0], cfg, dtype) if cfg.mla
                else L.init_attention(ks[0], cfg, dtype))
        return {
            "attn_norm": jnp.ones((d,), dtype),
            "attn": attn,
            "mlp_norm": jnp.ones((d,), dtype),
            "moe": L.init_moe(ks[1], cfg, dtype),
        }
    if kind == "moe_dense":  # deepseek first layer: MLA attn + dense FFN
        attn = (L.init_mla(ks[0], cfg, dtype) if cfg.mla
                else L.init_attention(ks[0], cfg, dtype))
        ff = cfg.d_ff if cfg.d_ff else cfg.moe_d_ff * (cfg.top_k +
                                                       cfg.n_shared_experts)
        return {
            "attn_norm": jnp.ones((d,), dtype),
            "attn": attn,
            "mlp_norm": jnp.ones((d,), dtype),
            "mlp": L.init_mlp(ks[1], d, ff, dtype),
        }
    if kind == "ssm":
        return {
            "norm": jnp.ones((d,), dtype),
            "mamba": S.init_mamba2(ks[0], cfg, dtype),
        }
    raise ValueError(kind)


def _stack(trees: list):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def scan_or_unroll(cfg, body, init, xs):
    """lax.scan normally; Python-unrolled when cfg.unroll_stack (roofline
    probe compiles need per-layer HLO cost visible to cost_analysis)."""
    if not cfg.unroll_stack:
        return jax.lax.scan(body, init, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        carry, y = body(carry, jax.tree_util.tree_map(lambda a: a[i], xs))
        ys.append(y)
    stacked = (jax.tree_util.tree_map(lambda *z: jnp.stack(z), *ys)
               if ys and ys[0] is not None else None)
    return carry, stacked


def init_lm(key, cfg, dtype=jnp.float32) -> Params:
    d, v = cfg.d_model, cfg.vocab_size
    k_emb, k_blocks, k_head, k_shared = jax.random.split(key, 4)
    params: dict = {
        "embed": jax.random.normal(k_emb, (v, d), dtype) * 0.02,
        "final_norm": jnp.ones((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(k_head, (v, d), dtype) * 0.02

    keys = jax.random.split(k_blocks, max(cfg.n_layers, 1))
    fam = cfg.family
    if fam in ("dense", "vlm", "audio"):
        params["blocks"] = _stack(
            [_init_block(keys[i], cfg, "dense", dtype)
             for i in range(cfg.n_layers)])
    elif fam == "moe":
        nd = cfg.first_dense_layers
        if nd:
            params["first_blocks"] = [
                _init_block(keys[i], cfg, "moe_dense", dtype)
                for i in range(nd)]
        params["blocks"] = _stack(
            [_init_block(keys[i], cfg, "moe", dtype)
             for i in range(nd, cfg.n_layers)])
    elif fam == "ssm":
        params["blocks"] = _stack(
            [_init_block(keys[i], cfg, "ssm", dtype)
             for i in range(cfg.n_layers)])
    elif fam == "hybrid":
        params["blocks"] = _stack(
            [_init_block(keys[i], cfg, "ssm", dtype)
             for i in range(cfg.n_layers)])
        params["shared_attn"] = _init_block(k_shared, cfg, "dense", dtype)
    else:
        raise ValueError(fam)
    return params


# ---------------------------------------------------------------------------
# Block applications (scan bodies).
# ---------------------------------------------------------------------------

def _dense_block(bp, x, cfg, lut, cache, pos, impl, causal=True):
    h = L.rms_norm(x, bp["attn_norm"], cfg.norm_eps)
    a, new_cache = L.apply_attention(bp["attn"], h, cfg, lut=lut, cache=cache,
                                     pos=pos, causal=causal, impl=impl)
    # Serving: one reshard point per residual — row-parallel outputs arrive
    # reduce-scattered (T on model); without the pin every consumer
    # re-gathers x separately in f32 (5×4 GiB/layer at llama prefill;
    # §Perf P3).  Training keeps free propagation: the pin forces gathers
    # inside the remat'd backward (internlm2 train 114→165 GiB, refuted).
    pin = constrain_batch if cache is not None else (lambda z: z)
    x = pin(x + a)
    h = L.rms_norm(x, bp["mlp_norm"], cfg.norm_eps)
    x = pin(x + L.apply_mlp(bp["mlp"], h, lut=lut, impl=impl))
    return x, new_cache


def _moe_block(bp, x, cfg, lut, cache, pos, impl, with_routing=False):
    h = L.rms_norm(x, bp["attn_norm"], cfg.norm_eps)
    if cfg.mla:
        a, new_cache = L.apply_mla(bp["attn"], h, cfg, lut=lut, cache=cache,
                                   pos=pos, impl=impl)
    else:
        a, new_cache = L.apply_attention(bp["attn"], h, cfg, lut=lut,
                                         cache=cache, pos=pos, impl=impl)
    x = x + a
    h = L.rms_norm(x, bp["mlp_norm"], cfg.norm_eps)
    if "moe" in bp and with_routing:
        y, aux, eids = L.apply_moe(bp["moe"], h, cfg, lut=lut, impl=impl,
                                   with_routing=True)
        return x + y, new_cache, aux, eids
    if "moe" in bp:
        y, aux = L.apply_moe(bp["moe"], h, cfg, lut=lut, impl=impl)
    else:
        y, aux = L.apply_mlp(bp["mlp"], h, lut=lut, impl=impl), 0.0
    if with_routing:  # dense block inside an eids-carrying stack: no router
        raise ValueError("with_routing requires an MoE block")
    return x + y, new_cache, aux


def _ssm_block(bp, x, cfg, lut, cache, impl):
    h = L.rms_norm(x, bp["norm"], cfg.norm_eps)
    y, new_cache = S.apply_mamba2(bp["mamba"], h, cfg, lut=lut, cache=cache,
                                  impl=impl)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# Stack runners.
# ---------------------------------------------------------------------------

def _run_stack(params, x, cfg, *, lut, caches, pos, impl,
               with_routing=False):
    """Scan homogeneous stacked blocks; returns (x, new_caches, aux_sum).

    ``with_routing=True`` (MoE stacks only) threads each layer's top-k
    expert ids out as an extra scan output and returns
    ``(x, new_caches, aux_sum, routing)`` with routing (L, n_tok, k) int32
    — the host-side signal the tiered residency manager plans fetches
    from (serve/residency.py)."""
    fam = cfg.family
    if with_routing and fam != "moe":
        raise ValueError(f"with_routing needs an MoE stack, got {fam!r}")

    def body(carry, xs):
        x, aux = carry
        bp, cache = xs
        cache = cache if isinstance(cache, dict) else None  # placeholder xs
        if fam in ("dense", "vlm", "audio"):
            x, nc = _dense_block(bp, x, cfg, lut, cache, pos, impl)
            return (x, aux), nc
        if fam == "moe":
            if with_routing:
                x, nc, a, eids = _moe_block(bp, x, cfg, lut, cache, pos,
                                            impl, with_routing=True)
                return (x, aux + a), (nc, eids)
            x, nc, a = _moe_block(bp, x, cfg, lut, cache, pos, impl)
            return (x, aux + a), nc
        if fam in ("ssm", "hybrid"):
            x, nc = _ssm_block(bp, x, cfg, lut, cache, impl)
            return (x, aux), nc
        raise ValueError(fam)

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), new_caches = scan_or_unroll(cfg, body, (x, jnp.float32(0.0)),
                                          (params, caches))
    if with_routing:
        new_caches, routing = new_caches
        return x, new_caches, aux, routing
    return x, new_caches, aux


def _hybrid_segments(cfg):
    """Zamba2: shared attn applied after every ``attn_period`` mamba blocks.

    Returns list of (start, end) mamba segments; a shared-attn application
    follows every segment except the last.
    """
    per = cfg.attn_period
    n = cfg.n_layers
    bounds = list(range(per, n, per))
    segs, prev = [], 0
    for b in bounds:
        segs.append((prev, b))
        prev = b
    segs.append((prev, n))
    return segs


def forward(params: Params, cfg, tokens: Optional[jax.Array] = None, *,
            embeds: Optional[jax.Array] = None, caches=None, pos=None,
            lut=None, impl: str = "auto", return_hidden: bool = False,
            return_routing: bool = False):
    """Full forward pass.

    tokens: (B, T) int32 — embedded via the table; embeds: (B, T', d)
    modality-frontend outputs, prepended when both given (VLM) or used
    alone (audio).  Returns (logits, new_caches, aux_loss).

    ``return_hidden=True`` skips the LM head and returns the final normed
    hidden states instead of logits — the chunked-CE training path computes
    head matmul + softmax per sequence chunk so the (B, T, V) logits tensor
    never materializes (see train.steps.chunked_cross_entropy).

    ``return_routing=True`` (MoE family only) appends the per-layer top-k
    expert ids of the *stacked* MoE layers — (L_moe, B*T, k) int32 — to
    the return tuple; unrolled first-dense layers have no router and
    contribute nothing.  Consumed host-side by the tiered expert-residency
    manager (serve/residency.py).
    """
    if tokens is not None:
        x = L.embed(params["embed"], tokens, lut)
        if embeds is not None:
            x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    else:
        x = embeds
    # Pin activations to batch sharding right after the vocab gather — SPMD
    # otherwise inherits the embed table's sharding and replicates (the
    # "involuntary full rematerialization" warning in the dry-run).
    x = constrain_batch(x)
    cfg_dtype = x.dtype

    aux_total = jnp.float32(0.0)
    new_caches: dict = {}
    fam = cfg.family
    routing = None
    if return_routing and fam != "moe":
        raise ValueError(f"return_routing needs family 'moe', got {fam!r}")

    if fam == "moe" and "first_blocks" in params:
        fb_caches = (caches or {}).get("first", [None] * len(params["first_blocks"]))
        ncs = []
        for bp, c in zip(params["first_blocks"], fb_caches):
            x, nc, a = _moe_block(bp, x, cfg, lut, c, pos, impl)
            aux_total = aux_total + a
            ncs.append(nc)
        new_caches["first"] = ncs

    if fam == "hybrid":
        segs = _hybrid_segments(cfg)
        blk_caches = (caches or {}).get("blocks")
        attn_caches = (caches or {}).get("attn", [None] * (len(segs) - 1))
        new_blk, new_attn = [], []
        for si, (s, e) in enumerate(segs):
            sub = jax.tree_util.tree_map(lambda a_: a_[s:e], params["blocks"])
            subc = (jax.tree_util.tree_map(lambda a_: a_[s:e], blk_caches)
                    if blk_caches is not None else _none_caches(e - s))
            x, nc, _ = _run_stack(sub, x, cfg, lut=lut, caches=subc,
                                  pos=pos, impl=impl)
            new_blk.append(nc)
            if si < len(segs) - 1:
                x, nac = _dense_block(params["shared_attn"], x, cfg, lut,
                                      attn_caches[si], pos, impl)
                new_attn.append(nac)
        new_caches["blocks"] = (
            jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs), *new_blk)
            if new_blk[0] is not None else None)
        new_caches["attn"] = new_attn
    else:
        blk_caches = (caches or {}).get("blocks")
        n_stacked = cfg.n_layers - (cfg.first_dense_layers
                                    if fam == "moe" else 0)
        if blk_caches is None:
            blk_caches = _none_caches(n_stacked)
        if return_routing:
            x, nc, aux, routing = _run_stack(
                params["blocks"], x, cfg, lut=lut, caches=blk_caches,
                pos=pos, impl=impl, with_routing=True)
        else:
            x, nc, aux = _run_stack(params["blocks"], x, cfg, lut=lut,
                                    caches=blk_caches, pos=pos, impl=impl)
        aux_total = aux_total + aux
        new_caches["blocks"] = nc

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        if return_routing:
            return x, new_caches, aux_total, routing
        return x, new_caches, aux_total
    head = params.get("lm_head", params["embed"])
    logits = L.linear(x, head, lut, impl=impl)
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    if return_routing:
        return logits, new_caches, aux_total, routing
    return logits, new_caches, aux_total


def _none_caches(n: int):
    """Broadcastable 'no cache' xs for scan: None isn't scannable, so use a
    zero-size per-layer placeholder."""
    return jnp.zeros((n, 0), jnp.float32)


# ---------------------------------------------------------------------------
# Cache construction.
# ---------------------------------------------------------------------------

def init_caches(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    """Stacked per-layer caches for serving."""
    fam = cfg.family

    def one_attn():
        if cfg.mla:
            return L.init_mla_cache(cfg, batch, max_len, dtype)
        return L.init_kv_cache(cfg, batch, max_len, dtype)

    if fam in ("dense", "vlm", "audio"):
        return {"blocks": _stack([one_attn() for _ in range(cfg.n_layers)])}
    if fam == "moe":
        nd = cfg.first_dense_layers
        out = {"blocks": _stack([one_attn()
                                 for _ in range(cfg.n_layers - nd)])}
        if nd:
            out["first"] = [one_attn() for _ in range(nd)]
        return out
    if fam == "ssm":
        return {"blocks": _stack([S.init_ssm_cache(cfg, batch)
                                  for _ in range(cfg.n_layers)])}
    if fam == "hybrid":
        segs = _hybrid_segments(cfg)
        return {
            "blocks": _stack([S.init_ssm_cache(cfg, batch)
                              for _ in range(cfg.n_layers)]),
            "attn": [L.init_kv_cache(cfg, batch, max_len, dtype)
                     for _ in range(len(segs) - 1)],
        }
    raise ValueError(fam)


def cache_batch_time_axes(cfg):
    """Per-leaf ``(batch_axis, time_axis)`` for this config's serving cache.

    The paged KV pool (serve/kv_cache.py) slices and scatters cache leaves
    along their batch (slot/page) and time axes.  Rather than hard-coding
    each layout — stacked ``(L, B, T, ...)`` block leaves, per-layer
    ``(B, T, ...)`` list leaves, MLA latent planes, int8-KV scale planes —
    the axes are derived structurally: ``eval_shape`` over
    :func:`init_caches` at distinguishing batch/length values, the axis
    that moves with each argument is the answer.  The result is a pytree
    of ``(batch, time)`` tuples matching the cache structure (read it with
    ``is_leaf=lambda x: isinstance(x, tuple)``).

    Families whose recurrent state has no time axis (ssm/hybrid mamba
    caches) raise ``ValueError`` — they cannot back a paged KV pool.
    """
    a = jax.eval_shape(lambda: init_caches(cfg, 2, 7))
    b = jax.eval_shape(lambda: init_caches(cfg, 3, 7))
    c = jax.eval_shape(lambda: init_caches(cfg, 2, 9))

    def axes(sa, sb, sc):
        batch = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                 if x != y]
        time = [i for i, (x, y) in enumerate(zip(sa.shape, sc.shape))
                if x != y]
        if len(batch) != 1 or len(time) != 1:
            raise ValueError(
                f"cache leaf {sa.shape} has no unambiguous (batch, time) "
                f"axes — family {cfg.family!r} cannot back a paged KV pool")
        if time[0] != batch[0] + 1:
            raise ValueError(
                f"cache leaf {sa.shape}: time axis {time[0]} is not "
                f"adjacent to batch axis {batch[0]}")
        return (batch[0], time[0])

    return jax.tree_util.tree_map(axes, a, b, c)
