"""Encoder-decoder backbone (seamless-m4t family).

Audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S, d) straight to the encoder.  The
decoder is a standard causal stack with cross-attention; encoder K/V are
projected once at prefill and cached.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.partition import constrain_batch

from . import layers as L
from .lm import _stack, _none_caches, scan_or_unroll

Params = dict


def init_encdec(key, cfg, dtype=jnp.float32) -> Params:
    d, v = cfg.d_model, cfg.vocab_size
    ks = jax.random.split(key, 4 + cfg.encoder_layers + cfg.decoder_layers)
    enc_blocks = []
    for i in range(cfg.encoder_layers):
        kk = jax.random.split(ks[4 + i], 2)
        enc_blocks.append({
            "attn_norm": jnp.ones((d,), dtype),
            "attn": L.init_attention(kk[0], cfg, dtype),
            "mlp_norm": jnp.ones((d,), dtype),
            "mlp": L.init_mlp(kk[1], d, cfg.d_ff, dtype),
        })
    dec_blocks = []
    for i in range(cfg.decoder_layers):
        kk = jax.random.split(ks[4 + cfg.encoder_layers + i], 3)
        dec_blocks.append({
            "attn_norm": jnp.ones((d,), dtype),
            "attn": L.init_attention(kk[0], cfg, dtype),
            "cross_norm": jnp.ones((d,), dtype),
            "cross": L.init_attention(kk[1], cfg, dtype),
            "mlp_norm": jnp.ones((d,), dtype),
            "mlp": L.init_mlp(kk[2], d, cfg.d_ff, dtype),
        })
    return {
        "dec_embed": jax.random.normal(ks[0], (v, d), dtype) * 0.02,
        "encoder": _stack(enc_blocks),
        "decoder": _stack(dec_blocks),
        "enc_final_norm": jnp.ones((d,), dtype),
        "dec_final_norm": jnp.ones((d,), dtype),
        "lm_head": jax.random.normal(ks[1], (v, d), dtype) * 0.02,
    }


def encode(params: Params, cfg, embeds: jax.Array, *, lut=None,
           impl: str = "auto") -> jax.Array:
    """Bidirectional encoder over precomputed frame embeddings."""

    def body(x, bp):
        h = L.rms_norm(x, bp["attn_norm"], cfg.norm_eps)
        a, _ = L.apply_attention(bp["attn"], h, cfg, lut=lut, cache=None,
                                 pos=None, causal=False, impl=impl)
        x = x + a
        h = L.rms_norm(x, bp["mlp_norm"], cfg.norm_eps)
        return x + L.apply_mlp(bp["mlp"], h, lut=lut, impl=impl), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = scan_or_unroll(cfg, body, embeds, params["encoder"])
    return L.rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def project_enc_kv_all(params: Params, cfg, enc_out: jax.Array, *,
                       lut=None, impl: str = "auto"):
    """Cross-attention K/V for every decoder layer, stacked (L, B, S, H, hd)."""

    def body(_, bp):
        k, v = L.project_enc_kv(bp["cross"], enc_out, cfg, lut=lut, impl=impl)
        return None, (k, v)

    _, (ks, vs) = scan_or_unroll(cfg, body, None, params["decoder"])
    return ks, vs


def decode_stack(params: Params, cfg, x: jax.Array, enc_k, enc_v, *,
                 caches=None, pos=None, lut=None, impl: str = "auto"):
    """Decoder stack: causal self-attn (cached) + cross-attn + FFN."""

    def body(carry, xs):
        x = carry
        bp, cache, ek, ev = xs
        cache = cache if isinstance(cache, dict) else None
        h = L.rms_norm(x, bp["attn_norm"], cfg.norm_eps)
        a, nc = L.apply_attention(bp["attn"], h, cfg, lut=lut, cache=cache,
                                  pos=pos, causal=True, impl=impl)
        x = x + a
        h = L.rms_norm(x, bp["cross_norm"], cfg.norm_eps)
        x = x + L.apply_cross_attention(bp["cross"], h, ek, ev, cfg,
                                        lut=lut, impl=impl)
        h = L.rms_norm(x, bp["mlp_norm"], cfg.norm_eps)
        return x + L.apply_mlp(bp["mlp"], h, lut=lut, impl=impl), nc

    if cfg.remat:
        body = jax.checkpoint(body)
    if caches is None:
        caches = _none_caches(cfg.decoder_layers)
    x, new_caches = scan_or_unroll(cfg, body, x,
                                   (params["decoder"], caches, enc_k, enc_v))
    return x, new_caches


def forward(params: Params, cfg, enc_embeds: jax.Array,
            dec_tokens: jax.Array, *, caches=None, pos=None, lut=None,
            impl: str = "auto", return_hidden: bool = False):
    """Full enc-dec forward (training / prefill): encode then decode.

    Returns (logits, new_caches) where new_caches includes the projected
    encoder K/V for subsequent decode steps.  ``return_hidden=True`` skips
    the LM head (chunked-CE training path).
    """
    enc_out = encode(params, cfg, enc_embeds, lut=lut, impl=impl)
    enc_k, enc_v = project_enc_kv_all(params, cfg, enc_out, lut=lut, impl=impl)
    x = constrain_batch(L.embed(params["dec_embed"], dec_tokens, lut))
    self_caches = (caches or {}).get("self")
    x, new_self = decode_stack(params, cfg, x, enc_k, enc_v,
                               caches=self_caches, pos=pos, lut=lut, impl=impl)
    x = L.rms_norm(x, params["dec_final_norm"], cfg.norm_eps)
    new_caches = {"self": new_self, "enc_k": enc_k, "enc_v": enc_v}
    if return_hidden:
        return x, new_caches
    logits = L.linear(x, params["lm_head"], lut, impl=impl)
    return logits, new_caches


def decode_step(params: Params, cfg, token: jax.Array, caches, pos, *,
                lut=None, impl: str = "auto"):
    """One decoder step against cached self K/V + encoder K/V."""
    x = L.embed(params["dec_embed"], token, lut)
    x, new_self = decode_stack(params, cfg, x, caches["enc_k"],
                               caches["enc_v"], caches=caches["self"],
                               pos=pos, lut=lut, impl=impl)
    x = L.rms_norm(x, params["dec_final_norm"], cfg.norm_eps)
    logits = L.linear(x, params["lm_head"], lut, impl=impl)
    return logits, {"self": new_self, "enc_k": caches["enc_k"],
                    "enc_v": caches["enc_v"]}


def init_dec_caches(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return _stack([L.init_kv_cache(cfg, batch, max_len, dtype)
                   for _ in range(cfg.decoder_layers)])
