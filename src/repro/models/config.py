"""Model configuration — one dataclass covering every assigned family."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"   # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    vocab_size: int = 1024
    head_dim: Optional[int] = None          # default d_model // n_heads
    qk_norm: bool = False                   # qwen3
    qkv_bias: bool = False                  # qwen2
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0                      # routed experts (0 = dense FFN)
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                       # per-expert hidden
    first_dense_layers: int = 0             # deepseek: layer 0 stays dense
    capacity_factor: float = 1.25
    moe_expert_scan: bool = False           # edge mode: decode 1 expert at a time
    # shard_map local-routing MoE (§Perf DP3): each device routes its LOCAL
    # tokens to its LOCAL expert shard — replaces SPMD's dense global
    # dispatch (token gather + f32 combine all-reduce) with one bf16 psum
    # of the outputs over the model axis.  Capacity becomes per-shard.
    moe_local_dispatch: bool = False

    # --- MLA (deepseek-style latent attention) ------------------------------
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0                    # 0 = dense q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # --- SSM (mamba2/SSD) ----------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_n_groups: int = 1
    ssm_chunk: int = 256

    # --- hybrid (zamba2) -----------------------------------------------------
    attn_period: int = 0                    # shared attn block every N layers

    # --- enc-dec (seamless) ----------------------------------------------------
    encoder_layers: int = 0
    decoder_layers: int = 0

    # --- modality frontend stubs ----------------------------------------------
    frontend: Optional[str] = None          # 'audio' | 'vision'
    n_patches: int = 256                    # vision stub: patches per image

    # --- numerics / compression ----------------------------------------------
    remat: bool = True                      # activation checkpoint scan bodies
    logits_softcap: float = 0.0
    unroll_stack: bool = False              # Python-loop layers (probe compiles)
    # beyond-paper: the paper's int8 quantizer applied to the KV cache —
    # halves decode's dominant bandwidth/capacity term (per-token-per-head
    # absmax scales; see layers.init_kv_cache / _dequant_cache)
    kv_cache_bits: int = 16                 # 16 (bf16) | 8 (int8 + scales)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs only (DESIGN.md §Arch-applicability)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads

        def attn_params():
            if self.mla:
                q = (d * self.q_lora_rank + self.q_lora_rank * nq *
                     (self.qk_nope_head_dim + self.qk_rope_head_dim)) \
                    if self.q_lora_rank else \
                    d * nq * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                kv = d * (self.kv_lora_rank + self.qk_rope_head_dim)
                kv += self.kv_lora_rank * nq * (self.qk_nope_head_dim +
                                                self.v_head_dim)
                o = nq * self.v_head_dim * d
                return q + kv + o
            return d * hd * (nq + 2 * nkv) + nq * hd * d

        def ffn_params(hidden):
            return 3 * d * hidden  # SwiGLU

        def moe_params():
            routed = self.n_experts * ffn_params(self.moe_d_ff)
            shared = self.n_shared_experts * ffn_params(self.moe_d_ff)
            router = d * self.n_experts
            return routed + shared + router

        def mamba_params():
            di, n, g = self.d_inner, self.ssm_state, self.ssm_n_groups
            h = self.ssm_heads
            in_proj = d * (2 * di + 2 * g * n + h)
            conv = (di + 2 * g * n) * self.ssm_conv
            out = di * d
            return in_proj + conv + out + 2 * h + di  # A, dt_bias, D-ish

        # embeddings (+ untied head) + per-layer/final norms
        emb = v * d * (1 if self.tie_embeddings else 2)
        norms = d * (2 * self.n_layers + 1)
        if self.qk_norm:
            norms += 2 * hd * self.n_layers

        if self.family == "encdec":
            enc = self.encoder_layers * (attn_params() + ffn_params(ff))
            dec = self.decoder_layers * (2 * attn_params() + ffn_params(ff))
            return enc + dec + emb + norms
        if self.family == "ssm":
            return self.n_layers * mamba_params() + emb + norms
        if self.family == "hybrid":
            shared = attn_params() + ffn_params(ff)  # one shared block
            return self.n_layers * mamba_params() + shared + emb + norms
        if self.is_moe:
            moe_layers = self.n_layers - self.first_dense_layers
            per = moe_params()
            dense = ffn_params(ff if ff else self.moe_d_ff)
            total = (moe_layers * (attn_params() + per) +
                     self.first_dense_layers * (attn_params() + dense))
            return total + emb + norms
        return self.n_layers * (attn_params() + ffn_params(ff)) + emb + norms

    def n_active_params(self) -> int:
        """Active params per token (MoE: shared + top_k experts only)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        act_ffn = (self.top_k + self.n_shared_experts) * 3 * d * self.moe_d_ff
        full_ffn = (self.n_experts + self.n_shared_experts) * 3 * d * self.moe_d_ff
        per_layer_delta = full_ffn - act_ffn
        moe_layers = self.n_layers - self.first_dense_layers
        return self.n_params() - moe_layers * per_layer_delta
