"""Model primitives — functional layers over plain param pytrees.

Every linear weight is stored ``(out, in)`` and may be a dense array, a
``QuantLinear`` (int8) or a ``PackedLinear`` (Tiny-QMoE compressed); the
``linear`` dispatcher below routes to the fused kernels, which is how the
paper's technique becomes a first-class property of *every* architecture in
the zoo rather than a bolt-on.  Tile-laid ``PackedLinear`` /
``TiledPackedLinear`` weights (``tile_n > 0``) hit the
decode→dequant→matmul megakernel through ``ops.decode_dequant_matmul`` /
``ops.tiled_decode_dequant_matmul`` on single devices AND under sharded
meshes (a shard_map wrapper splits the fused grid per device; see the
mesh-dispatch rules on those ops) — the dense weight never materializes;
pass ``impl='unfused'`` to force the legacy two-step path.  Stacked MoE
expert weights — where ~all of a QMoE-class model's bytes live — go
through the grouped expert megakernel (``_expert_ffn`` →
``ops.grouped_decode_dequant_matmul``), so the compressed-resident
invariant holds for expert stacks too: peak HBM = compressed experts +
gathered activations + one VMEM tile.

Param trees are plain nested dicts so that (a) ``lax.scan`` over stacked
layers works out of the box, (b) sharding rules match on path names, and
(c) checkpointing is pure numpy.
"""
from __future__ import annotations

import collections
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.compressed import PackedLinear, QuantLinear, TiledPackedLinear
from repro.kernels import ops
from repro.sharding.partition import constrain

Params = Any  # nested dict pytree


# ---------------------------------------------------------------------------
# Linear dispatch — dense | int8 | compressed.
# ---------------------------------------------------------------------------

def linear(x: jax.Array, w, lut=None, bias=None, impl: str = "auto"):
    """y = x @ W.T (+ bias) for any weight container."""
    if isinstance(w, TiledPackedLinear):
        y = ops.tiled_decode_dequant_matmul(x, w, lut, out_dtype=x.dtype,
                                            impl=impl)
    elif isinstance(w, PackedLinear):
        y = ops.decode_dequant_matmul(x, w, lut, out_dtype=x.dtype, impl=impl)
    elif isinstance(w, QuantLinear):
        y = ops.dequant_matmul(x, w.values, w.scale, w.zero,
                               out_dtype=x.dtype, impl=impl)
    else:
        y = jnp.einsum("...k,nk->...n", x, w.astype(x.dtype))
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


# Trace-time materialization probe: which container classes decoded to a
# dense HBM tensor, keyed by kind ('packed', 'packed_stacked', 'tiled',
# 'quant').  'packed_stacked' is the expert-plane key — the grouped fused
# MoE path must keep it at zero (the acceptance invariant "zero
# materialize_weight calls on expert planes"); tests assert on it.
MATERIALIZE_COUNTS = collections.Counter()


def materialize_weight(w, lut=None, dtype=None):
    """Dense view of any weight container (unfused fallbacks, MLA absorb).

    ``dtype`` is honored identically on every container branch —
    ``None`` decodes PackedLinear/TiledPackedLinear *and* QuantLinear to
    bf16 (and leaves dense weights untouched); an explicit dtype is passed
    through unchanged everywhere.
    """
    if isinstance(w, (PackedLinear, TiledPackedLinear)):
        kind = "tiled" if isinstance(w, TiledPackedLinear) else "packed"
        if w.codes.ndim > (3 if kind == "tiled" else 2):
            kind += "_stacked"
        MATERIALIZE_COUNTS[kind] += 1
        return w.materialize(lut, jnp.bfloat16 if dtype is None else dtype)
    if isinstance(w, QuantLinear):
        MATERIALIZE_COUNTS["quant"] += 1
        return w.materialize(jnp.bfloat16 if dtype is None else dtype)
    return w if dtype is None else w.astype(dtype)


def embed(w, ids: jax.Array, lut=None) -> jax.Array:
    """Embedding lookup from dense or int8 tables (rows = vocab)."""
    if isinstance(w, QuantLinear):
        rows = w.values[ids].astype(jnp.float32)
        return ((rows - w.zero[ids, 0][..., None]) *
                w.scale[ids, 0][..., None]).astype(jnp.bfloat16)
    if isinstance(w, PackedLinear):  # decode then gather (rare path)
        dense = w.materialize(lut, jnp.bfloat16)
        return dense[ids]
    return w[ids]


# ---------------------------------------------------------------------------
# Norms + RoPE.
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(dt)


def rope_tables(positions: jax.Array, head_dim: int, theta: float):
    """cos/sin tables for given (possibly traced) positions.

    ``positions``: (T,) — one position track shared by the whole batch —
    or (B, T) per-row tracks (the continuous-batching decode step, where
    every slot sits at its own offset).  Returns (..., hd/2) matching."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, T, H, hd) — rotate pairs (split-half convention).  cos/sin
    are (T, hd/2) shared across the batch or (B, T, hd/2) per-row."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 3:
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
    else:
        c = cos[None, :, None, :]
        s = sin[None, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (qwen/llama/internlm family).
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(k1, (nq * hd, d), dtype) * s,
        "wk": jax.random.normal(k2, (nkv * hd, d), dtype) * s,
        "wv": jax.random.normal(k3, (nkv * hd, d), dtype) * s,
        "wo": jax.random.normal(k4, (d, nq * hd), dtype) * (1.0 / math.sqrt(nq * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    hd = cfg.resolved_head_dim
    if getattr(cfg, "kv_cache_bits", 16) == 8:
        # int8 cache + per-(token, head) absmax scales (paper's quantizer
        # pointed at the KV cache — beyond-paper; halves decode bandwidth)
        return {
            "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), jnp.int8),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, max_len, cfg.n_kv_heads, 1),
                                 jnp.float32),
            "v_scale": jnp.zeros((batch, max_len, cfg.n_kv_heads, 1),
                                 jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
    }


def _quant_kv(x: jax.Array):
    """(B, T, H, hd) float → (int8 codes, f32 scales) per (token, head)."""
    m = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(m / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def _dequant_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _kv_write(dst: jax.Array, src: jax.Array, pos) -> jax.Array:
    """Write ``src`` (B, T, ...) into the cache ``dst`` (B, L, ...) at
    ``pos``.

    Scalar ``pos`` (train / one-shot serving): a dynamic-slice update at
    one shared offset.  Vector ``pos`` (B,) (the continuous-batching
    decode step — every slot at its own offset): a per-row scatter, which
    requires T == 1.
    """
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(dst, src, pos, axis=1)
    if src.shape[1] != 1:
        raise ValueError("per-slot (vector pos) cache writes decode one "
                         f"token at a time; got T={src.shape[1]}")
    return dst.at[jnp.arange(dst.shape[0]), pos].set(src[:, 0])


_BATCH = ("pod", "data")


def _model_axis_size() -> int:
    from repro.sharding.partition import current_mesh
    axis_sizes, _ = current_mesh()
    return axis_sizes.get("model", 1)


def _attend_full(q, k, v, causal: bool, impl: str, kv_chunk=None,
                 serving: bool = False):
    """Prefill/train attention: (B, T, H, hd) layout in, flash kernel inside.

    Model-axis placement must be CONSISTENT between q and k/v or SPMD
    reconciles the flash einsum with full-cache gathers (52 GiB at the 32k
    prefill; §Perf iteration 6):
      * kv heads divide TP   → all of q/k/v shard heads (classic TP).
      * GQA-narrow at SERVE (no backward) and q heads divide → q keeps its
        natural head TP, k/v replicate in bf16 (transient).  Avoids the
        cross-dim q reshard XLA lowers as a 4 GiB/layer f32 gather (§Perf
        P3: llama prefill −3 TiB).
      * GQA-narrow at TRAIN → q shards its TIME dim (context parallelism);
        replicated k/v would live through the backward (HBM 4.1→18.7
        GiB/dev, refuted §Perf 6b).

    ``kv_chunk``: override the jnp-flash chunk (probe compiles pass the full
    length so attention FLOPs are loop-free and visible to cost_analysis).
    """
    msize = _model_axis_size()
    hkv = k.shape[2]
    hq = q.shape[2]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if msize > 1 and hkv % msize == 0:
        qt = constrain(qt, _BATCH, "model", None, None)
        kt = constrain(kt, _BATCH, "model", None, None)
        vt = constrain(vt, _BATCH, "model", None, None)
    elif msize > 1 and serving and hq % msize == 0:
        qt = constrain(qt, _BATCH, "model", None, None)
        kt = constrain(kt, _BATCH, None, None, None)
        vt = constrain(vt, _BATCH, None, None, None)
    elif msize > 1:
        qt = constrain(qt, _BATCH, None, "model", None)
        kt = constrain(kt, _BATCH, None, None, None)
        vt = constrain(vt, _BATCH, None, None, None)
        # barrier: otherwise XLA hoists the flash body's f32 casts above
        # the reshard and gathers rope internals in f32 (2× the bytes)
        qt, kt, vt = jax.lax.optimization_barrier((qt, kt, vt))
    ot = ops.flash_attention(qt, kt, vt, causal=causal, impl=impl,
                             kv_chunk=kv_chunk)
    return ot.transpose(0, 2, 1, 3)


def _attend_cache_flash(q, cache_k, cache_v, pos, impl: str):
    """Prefill attention over an (updated) cache, flash semantics.

    The naive cached path materializes (T, L) logits — 128 GiB/dev at the
    32k prefill shape (§Perf iteration 2).  Flash with ``q_offset=pos``
    keeps the online-softmax running state only.
    """
    msize = _model_axis_size()
    def _c(x):
        if msize > 1 and x.shape[1] % msize == 0:
            return constrain(x, _BATCH, "model", None, None)
        return constrain(x, _BATCH, None, None, None)
    qt = _c(q.transpose(0, 2, 1, 3))
    kt = _c(cache_k.transpose(0, 2, 1, 3))
    vt = _c(cache_v.transpose(0, 2, 1, 3))
    ot = ops.flash_attention(qt, kt, vt, causal=True, q_offset=pos,
                             impl=impl)
    return ot.transpose(0, 2, 1, 3)


def _attend_cached(q, cache_k, cache_v, pos, t_new: int):
    """Decode attention over a cache: mask positions > pos+t_new-1.

    q: (B, T, Hq, hd); cache: (B, L, Hkv, hd); pos: scalar (traced ok) or
    per-row (B,) offsets (continuous batching).  Entries past a row's own
    position get -1e30 → exp underflows to exactly 0.0, so padded / stale
    cache regions contribute nothing — bitwise — to the softmax sums.
    """
    b, t, hq, hd = q.shape
    hkv = cache_k.shape[2]
    rep = hq // hkv
    lmax = cache_k.shape[1]
    qf = q.astype(jnp.float32).reshape(b, t, hkv, rep, hd)
    kf = cache_k.astype(jnp.float32)
    vf = cache_v.astype(jnp.float32)
    logits = jnp.einsum("btgrd,blgd->btgrl", qf, kf) / math.sqrt(hd)
    kpos = jnp.arange(lmax)
    qpos = jnp.asarray(pos)[..., None] + jnp.arange(t)   # (t,) or (B, t)
    mask = kpos <= qpos[..., None]                       # (t, L) or (B, t, L)
    mask = (mask[None, :, None, None, :] if mask.ndim == 2
            else mask[:, :, None, None, :])
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("btgrl,blgd->btgrd", p, vf)
    return out.reshape(b, t, hq, hd).astype(q.dtype)


def apply_attention(p: Params, x: jax.Array, cfg, *, lut=None,
                    cache: Optional[Params] = None, pos=None,
                    causal: bool = True, impl: str = "auto"):
    """Returns (y, new_cache). ``cache=None`` → full (train/prefill no-cache)
    attention; with cache: writes k/v at ``pos`` then attends ≤ pos."""
    b, t, d = x.shape
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads

    q = linear(x, p["wq"], lut, p.get("bq"), impl).reshape(b, t, nq, hd)
    k = linear(x, p["wk"], lut, p.get("bk"), impl).reshape(b, t, nkv, hd)
    v = linear(x, p["wv"], lut, p.get("bv"), impl).reshape(b, t, nkv, hd)

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    pos0 = 0 if pos is None else pos
    if jnp.ndim(pos0) == 1 and t != 1:
        raise ValueError("vector (per-slot) pos supports single-token "
                         f"decode only; got T={t}")
    positions = jnp.asarray(pos0)[..., None] + jnp.arange(t)
    cos, sin = rope_tables(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if cache is None:
        kvc = t if getattr(cfg, "unroll_stack", False) else None
        o = _attend_full(q, k, v, causal, impl, kv_chunk=kvc)
        new_cache = None
    else:
        if t == 1:
            # Decode: the fused shard-mapped projections emit y
            # column-sharded on model; reshaped to (B, 1, H, hd) that is an
            # inexpressible (heads × head_dim) fragment, and SPMD
            # reconciles it with the cache layout by fully rematerializing
            # the multi-GiB KV cache every step (dry-run decode
            # collectives 6 MiB → 1.3 TiB when unpinned).  Pin the tiny
            # fresh q/k/v to the cache's head placement — heads on model
            # when they divide, else replicated (constrain drops
            # non-dividing axes) — so the cache keeps its spec-time
            # sharding through the update.
            q = constrain(q, _BATCH, None, "model", None)
            k = constrain(k, _BATCH, None, "model", None)
            v = constrain(v, _BATCH, None, "model", None)
        int8_kv = cache["k"].dtype == jnp.int8
        if int8_kv:
            kq, ks = _quant_kv(k)
            vq, vs = _quant_kv(v)
            ck = _kv_write(cache["k"], kq, pos0)
            cv = _kv_write(cache["v"], vq, pos0)
            cks = _kv_write(cache["k_scale"], ks, pos0)
            cvs = _kv_write(cache["v_scale"], vs, pos0)
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
            ck_f = _dequant_kv(ck, cks, q.dtype)
            cv_f = _dequant_kv(cv, cvs, q.dtype)
        else:
            ck = _kv_write(cache["k"], k.astype(cache["k"].dtype), pos0)
            cv = _kv_write(cache["v"], v.astype(cache["v"].dtype), pos0)
            new_cache = {"k": ck, "v": cv}
            ck_f, cv_f = ck, cv
        if t == 1:
            o = _attend_cached(q, ck_f, cv_f, pos0, t)
        elif t == cache["k"].shape[1]:
            # Full prefill: the fresh (batch/head-sharded) k, v ARE the
            # cache content — attending over them directly avoids chunk-
            # slicing the sequence-sharded cache (52 GiB of gathers at the
            # 32k prefill shape; §Perf iteration 6).
            o = _attend_full(q, k, v, causal, impl, serving=True)
        else:  # chunked prefill: flash over the cache, never (T, L) logits
            o = _attend_cache_flash(q, ck_f, cv_f, pos0, impl)

    # NOTE(§Perf P1, refuted): explicitly resharding o from context-parallel
    # (T) back to head sharding before wo made collectives WORSE (llama
    # prefill 4.85→5.88 TiB; XLA lowers the cross-dim reshard as an f32
    # gather, not an all-to-all).  Leave propagation alone here.
    y = linear(o.reshape(b, t, nq * hd), p["wo"], lut, impl=impl)
    return y, new_cache


def apply_cross_attention(p: Params, x: jax.Array, enc_k, enc_v, cfg, *,
                          lut=None, impl: str = "auto"):
    """Decoder cross-attention over precomputed encoder K/V (B, S, H, hd)."""
    b, t, d = x.shape
    hd = cfg.resolved_head_dim
    nq = cfg.n_heads
    q = linear(x, p["wq"], lut, p.get("bq"), impl).reshape(b, t, nq, hd)
    o = _attend_full(q, enc_k, enc_v, causal=False, impl=impl)
    return linear(o.reshape(b, t, nq * hd), p["wo"], lut, impl=impl)


def project_enc_kv(p: Params, enc_out: jax.Array, cfg, *, lut=None,
                   impl: str = "auto"):
    b, s, d = enc_out.shape
    hd = cfg.resolved_head_dim
    nkv = cfg.n_kv_heads
    k = linear(enc_out, p["wk"], lut, p.get("bk"), impl).reshape(b, s, nkv, hd)
    v = linear(enc_out, p["wv"], lut, p.get("bv"), impl).reshape(b, s, nkv, hd)
    return k, v


# ---------------------------------------------------------------------------
# MLA — DeepSeek latent attention (compressed KV cache).
# ---------------------------------------------------------------------------

def init_mla(key, cfg, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    nq = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    p = {}
    if cfg.q_lora_rank:
        p["wq_a"] = jax.random.normal(ks[0], (cfg.q_lora_rank, d), dtype) * s
        p["q_a_norm"] = jnp.ones((cfg.q_lora_rank,), dtype)
        p["wq_b"] = jax.random.normal(ks[1], (nq * (dn + dr), cfg.q_lora_rank),
                                      dtype) / math.sqrt(cfg.q_lora_rank)
    else:
        p["wq"] = jax.random.normal(ks[0], (nq * (dn + dr), d), dtype) * s
    p["wkv_a"] = jax.random.normal(ks[2], (r + dr, d), dtype) * s
    p["kv_a_norm"] = jnp.ones((r,), dtype)
    p["wkv_b"] = jax.random.normal(ks[3], (nq * (dn + dv), r), dtype) / math.sqrt(r)
    p["wo"] = jax.random.normal(ks[4], (d, nq * dv), dtype) / math.sqrt(nq * dv)
    return p


def init_mla_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
    }


def _mla_q(p, x, cfg, lut, impl):
    b, t, _ = x.shape
    nq = cfg.n_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        qa = linear(x, p["wq_a"], lut, impl=impl)
        qa = rms_norm(qa, p["q_a_norm"], cfg.norm_eps)
        q = linear(qa, p["wq_b"], lut, impl=impl)
    else:
        q = linear(x, p["wq"], lut, impl=impl)
    q = q.reshape(b, t, nq, dn + dr)
    return q[..., :dn], q[..., dn:]


def apply_mla(p: Params, x: jax.Array, cfg, *, lut=None, cache=None,
              pos=None, impl: str = "auto"):
    """MLA attention; decode path uses the *absorbed* form so per-step cost
    scales with kv_lora_rank, matching the MLA memory/compute claim."""
    b, t, d = x.shape
    nq = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    pos0 = 0 if pos is None else pos
    if jnp.ndim(pos0) == 1 and t != 1:
        raise ValueError("vector (per-slot) pos supports single-token "
                         f"decode only; got T={t}")

    q_nope, q_rope = _mla_q(p, x, cfg, lut, impl)
    positions = jnp.asarray(pos0)[..., None] + jnp.arange(t)
    cos, sin = rope_tables(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    kv_a = linear(x, p["wkv_a"], lut, impl=impl)          # (b,t,r+dr)
    ckv = rms_norm(kv_a[..., :r], p["kv_a_norm"], cfg.norm_eps)
    k_rope = kv_a[..., r:].reshape(b, t, 1, dr)
    k_rope = apply_rope(k_rope, cos, sin).reshape(b, t, dr)

    wkv_b = materialize_weight(p["wkv_b"], lut, x.dtype)  # (nq*(dn+dv), r)
    wkv_b = wkv_b.reshape(nq, dn + dv, r)
    w_k = wkv_b[:, :dn]                                   # (nq, dn, r)
    w_v = wkv_b[:, dn:]                                   # (nq, dv, r)

    if cache is None:
        # Prefill/train: materialize per-head K/V (cheap at O(T) once).
        k_nope = jnp.einsum("btr,hdr->bthd", ckv.astype(x.dtype), w_k)
        v = jnp.einsum("btr,hdr->bthd", ckv.astype(x.dtype), w_v)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, t, nq, dr))],
            axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        # _attend_full scales by 1/sqrt(dn+dr) == MLA's score scale already.
        kvc = t if getattr(cfg, "unroll_stack", False) else None
        o = _attend_full(q_full, k_full, v, causal=True, impl=impl,
                         kv_chunk=kvc)
        new_cache = None
        o = o.astype(x.dtype)
        y = linear(o.reshape(b, t, nq * dv), p["wo"], lut, impl=impl)
        return y, new_cache

    # Cache updates (prefill writes T latents at pos0, decode writes 1;
    # vector pos0 scatters per-slot rows — continuous batching).
    cckv = _kv_write(cache["ckv"], ckv.astype(cache["ckv"].dtype), pos0)
    ckrope = _kv_write(cache["krope"], k_rope.astype(cache["krope"].dtype),
                       pos0)

    if t > 1:
        # Prefill: materialize per-head K/V (O(L) once) and run flash — the
        # absorbed path below would build (T, L) score tensors (528 GiB/dev
        # at 32k; §Perf iteration 2).  Full prefill (t == cache len) reads
        # the fresh latents, not the sequence-sharded cache (§Perf iter 6).
        full = t == cckv.shape[1]
        src_kv = ckv if full else cckv
        src_rope = k_rope if full else ckrope
        lmax = src_kv.shape[1]
        k_nope = jnp.einsum("blr,hdr->blhd", src_kv.astype(x.dtype), w_k)
        v_full = jnp.einsum("blr,hdr->blhd", src_kv.astype(x.dtype), w_v)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(src_rope[:, :, None].astype(x.dtype),
                                      (b, lmax, nq, dr))], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        if full:
            o = _attend_full(q_full, k_full, v_full, causal=True, impl=impl)
        else:
            o = _attend_cache_flash(q_full, k_full, v_full, pos0, impl)
        o = o.astype(x.dtype)
        y = linear(o.reshape(b, t, nq * dv), p["wo"], lut, impl=impl)
        return y, {"ckv": cckv, "krope": ckrope}

    # Decode (absorbed): score = qc·ckv + qr·krope over cached latents.
    qc = jnp.einsum("bthd,hdr->bthr", q_nope.astype(jnp.float32),
                    w_k.astype(jnp.float32))               # (b,t,h,r)
    s_nope = jnp.einsum("bthr,blr->bthl", qc, cckv.astype(jnp.float32))
    s_rope = jnp.einsum("bthd,bld->bthl", q_rope.astype(jnp.float32),
                        ckrope.astype(jnp.float32))
    logits = (s_nope + s_rope) / math.sqrt(dn + dr)
    lmax = cckv.shape[1]
    kpos = jnp.arange(lmax)
    qpos = jnp.asarray(pos0)[..., None] + jnp.arange(t)  # (t,) or (B, t)
    mask = kpos <= qpos[..., None]                       # (t, L) or (B, t, L)
    mask = mask[None, :, None, :] if mask.ndim == 2 else mask[:, :, None, :]
    logits = jnp.where(mask, logits, -1e30)
    attn = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bthl,blr->bthr", attn, cckv.astype(jnp.float32))
    o = jnp.einsum("bthr,hdr->bthd", o_lat, w_v.astype(jnp.float32))
    o = o.astype(x.dtype)
    y = linear(o.reshape(b, t, nq * dv), p["wo"], lut, impl=impl)
    return y, {"ckv": cckv, "krope": ckrope}


# ---------------------------------------------------------------------------
# SwiGLU MLP + MoE.
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(k1, (ff, d), dtype) / math.sqrt(d),
        "w_up": jax.random.normal(k2, (ff, d), dtype) / math.sqrt(d),
        "w_down": jax.random.normal(k3, (d, ff), dtype) / math.sqrt(ff),
    }


def apply_mlp(p: Params, x: jax.Array, *, lut=None, impl: str = "auto"):
    g = linear(x, p["w_gate"], lut, impl=impl)
    u = linear(x, p["w_up"], lut, impl=impl)
    return linear(jax.nn.silu(g) * u, p["w_down"], lut, impl=impl)


def init_moe(key, cfg, dtype=jnp.float32) -> Params:
    d, e, ffe = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": jax.random.normal(k1, (e, d), dtype) / math.sqrt(d),
        "experts": {
            "w_gate": jax.random.normal(k2, (e, ffe, d), dtype) / math.sqrt(d),
            "w_up": jax.random.normal(k3, (e, ffe, d), dtype) / math.sqrt(d),
            "w_down": jax.random.normal(k4, (e, d, ffe), dtype) / math.sqrt(ffe),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(k5, d, cfg.moe_d_ff * cfg.n_shared_experts,
                               dtype)
    return p


def _capacity(n_tokens: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(math.ceil(n_tokens * top_k / n_experts * factor))
    return max(4, min(c, n_tokens))


def _grouped_fused_ok(w, lut) -> bool:
    """True when an expert stack can take the grouped fused megakernel:
    a stacked PackedLinear (leading expert axis) in tile-major layout with
    a decode LUT in hand."""
    return (isinstance(w, PackedLinear) and getattr(w, "tile_n", 0) > 0
            and w.codes.ndim == 3 and lut is not None)


def _expert_ffn(experts: Params, xe: jax.Array, lut=None,
                impl: str = "auto", *, local: bool = False) -> jax.Array:
    """SwiGLU over capacity-gathered per-expert token blocks (E, cap, d).

    The three expert matmuls route through the grouped fused
    decode→dequant→matmul megakernel whenever the stack is a compressed
    PackedLinear — dense expert weights never materialize in HBM
    (``ops.grouped_decode_dequant_matmul``, which also owns the mesh
    dispatch, the unfused fallback, and the 'grouped_*' probes).
    ``local=True`` marks a caller already inside a shard_map that owns
    only its expert shard (the local-routing MoE): the shard-local
    ``ops.grouped_fused_local`` runs directly, no nested mesh dispatch —
    the caller gates eligibility before choosing this path.  Dense and
    QuantLinear stacks fall back to materialize + einsum.
    """
    def mm(h, w):
        if isinstance(w, PackedLinear) and w.codes.ndim == 3 \
                and lut is not None:
            if local:
                if _grouped_fused_ok(w, lut):
                    return ops.grouped_fused_local(
                        h, w, lut, out_dtype=h.dtype, impl=impl)
                # linear-layout stack inside shard_map: materialize the
                # local shard below (no probe — ops owns probes)
            else:
                return ops.grouped_decode_dequant_matmul(
                    h, w, lut, out_dtype=h.dtype, impl=impl)
        return jnp.einsum("ecx,eyx->ecy", h,
                          materialize_weight(w, lut, h.dtype))

    g = mm(xe, experts["w_gate"])
    u = mm(xe, experts["w_up"])
    return mm(jax.nn.silu(g) * u, experts["w_down"])


def _moe_compute(xf, router_w, wg, wu, wd, cfg, n_experts: int,
                 expert_offset, *, lut=None, impl: str = "auto",
                 local: bool = False):
    """Core top-k dispatch + expert FFN over a token matrix (n_tok, d).

    ``n_experts``/``expert_offset``: the LOCAL expert range this caller
    owns (global dispatch: all of them, offset 0; shard_map local
    dispatch: E/model_size per device).  Router logits always span the
    FULL expert set so gates are identical across shards; slots routed
    outside [offset, offset+n_experts) are dropped locally (they are
    served by the owning shard).

    ``wg``/``wu``/``wd`` may be dense (local) arrays or stacked weight
    containers — the expert FFN goes through :func:`_expert_ffn`, so
    compressed stacks hit the grouped fused megakernel instead of
    materializing (``local`` marks shard_map callers).
    Returns (y (n_tok, d), aux_loss).
    """
    n_tok, d = xf.shape
    e_full = router_w.shape[0]
    k = cfg.top_k
    router_logits = jnp.einsum("td,ed->te", xf.astype(jnp.float32),
                               router_w.astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)        # (n_tok, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Load-balance aux loss (Switch-style) over the FULL expert set.
    onehot = jax.nn.one_hot(expert_ids, e_full, dtype=jnp.float32)
    f = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
    pmean = jnp.mean(probs, axis=0)
    aux = e_full * jnp.sum(f * pmean)

    cap = _capacity(n_tok, k, e_full, cfg.capacity_factor)

    local_ids = expert_ids - expert_offset
    owned = (local_ids >= 0) & (local_ids < n_experts)     # (n_tok, k)
    oh_local = jax.nn.one_hot(jnp.where(owned, local_ids, n_experts),
                              n_experts, dtype=jnp.float32)
    flat_e = jnp.where(owned, local_ids, n_experts).reshape(-1)
    onehot_flat = oh_local.reshape(n_tok * k, n_experts)
    pos_in_e = jnp.cumsum(onehot_flat, axis=0) - onehot_flat
    slot = jnp.sum(pos_in_e * onehot_flat, axis=-1).astype(jnp.int32)
    keep = (slot < cap) & owned.reshape(-1)
    slot_c = jnp.where(keep, slot, cap)
    flat_e_c = jnp.where(keep, flat_e, 0)

    tok_idx = jnp.repeat(jnp.arange(n_tok), k)
    table = jnp.full((n_experts, cap + 1), n_tok, jnp.int32)
    table = table.at[flat_e_c, slot_c].set(
        jnp.where(keep, tok_idx, n_tok), mode="drop")
    gtable = jnp.zeros((n_experts, cap + 1), jnp.float32)
    gtable = gtable.at[flat_e_c, slot_c].set(
        jnp.where(keep, gate_vals.reshape(-1), 0.0), mode="drop")
    table = table[:, :cap]
    gtable = gtable[:, :cap]

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xe = xpad[table]                                       # (e_loc, cap, d)
    ye = _expert_ffn({"w_gate": wg, "w_up": wu, "w_down": wd}, xe,
                     lut, impl, local=local)

    out = jnp.zeros((n_tok + 1, d), xf.dtype)
    out = out.at[table].add(ye.astype(xf.dtype) *
                            gtable[..., None].astype(xf.dtype))
    return out[:n_tok], aux


def apply_moe_local(p: Params, x: jax.Array, cfg, *, lut=None,
                    impl: str = "auto"):
    """shard_map local-routing MoE (§Perf DP3, beyond-paper).

    Tokens stay on their (pod, data) shard; experts live on their model
    shard; each device dispatches its local tokens to its local experts
    and the partial outputs psum over "model" in bf16 — replacing SPMD's
    dense global dispatch (full-token gathers + f32 (E,cap,d) combine
    all-reduces).  Capacity is per-(token-shard, expert): slightly
    different drop behaviour than the global path; equal when dropless.

    Compressed expert stacks (tile-major stacked PackedLinear) enter the
    shard_map as *planes* — expert axis on "model" — and each device runs
    the grouped fused decode→dequant→matmul megakernel over its resident
    E/model compressed slab (probe 'grouped_fused_shard_map'): dense
    expert weights never exist, on any device.  Other containers keep the
    legacy shape: materialize the dense stack outside, shard it on the
    expert dim.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.sharding.partition import current_mesh

    axis_sizes, mesh = current_mesh()
    msize = axis_sizes.get("model", 1)
    e_full = cfg.n_experts
    b, t, d = x.shape
    batch_axes = tuple(a for a in ("pod", "data") if a in axis_sizes)
    experts = p["experts"]
    # resolve the session-default 'unfused' lever here too: the grouped
    # gate below decides the path before any ops entry point would
    impl = ops._resolve_unfused(impl)
    grouped = (impl != "unfused" and e_full % msize == 0
               and all(_grouped_fused_ok(experts[k], lut)
                       for k in ("w_gate", "w_up", "w_down")))
    router_w = materialize_weight(p["router"], lut, jnp.float32)

    espec = P("model", None, None)
    xspec = P(batch_axes if batch_axes else None, None, None)

    def local_fn(x_loc, rw, lut_l, wg_l, wu_l, wd_l):
        bl, tl, _ = x_loc.shape
        xf = x_loc.reshape(bl * tl, d)
        midx = jax.lax.axis_index("model")
        y, aux = _moe_compute(xf, rw, wg_l, wu_l, wd_l, cfg,
                              e_full // msize, midx * (e_full // msize),
                              lut=lut_l, impl=impl, local=grouped)
        y = jax.lax.psum(y.astype(x_loc.dtype), "model")
        aux = jax.lax.pmean(aux, "model")
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return y.reshape(bl, tl, d), aux

    if grouped:
        # Compressed planes cross into the shard_map expert-sharded: the
        # induced gather moves compressed bytes, never dense experts.
        ops.DISPATCH_COUNTS["grouped_fused_shard_map"] += 1
        wg_in, wu_in, wd_in = (experts[k]
                               for k in ("w_gate", "w_up", "w_down"))
        wspecs = tuple(
            jax.tree_util.tree_map(
                lambda a: P(*(("model",) + (None,) * (a.ndim - 1))), w)
            for w in (wg_in, wu_in, wd_in))
        lut_in, lspec = lut, P(None, None)
    else:
        wg_in, wu_in, wd_in = (
            jax.lax.with_sharding_constraint(
                materialize_weight(experts[k], lut, x.dtype),
                jax.NamedSharding(mesh, espec))
            for k in ("w_gate", "w_up", "w_down"))
        wspecs = (espec, espec, espec)
        # dense path never touches the LUT inside; a 1-byte dummy keeps the
        # shard_map signature uniform
        lut_in, lspec = jnp.zeros((1, 1), jnp.uint8), P(None, None)

    y, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(xspec, P(None, None), lspec) + wspecs,
        out_specs=(xspec, P()),
        check_rep=False,
    )(x, router_w, lut_in, wg_in, wu_in, wd_in)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], x.reshape(b * t, d), lut=lut,
                          impl=impl).reshape(b, t, d)
    return y, aux


def apply_moe(p: Params, x: jax.Array, cfg, *, lut=None, impl: str = "auto",
              with_routing: bool = False):
    """Capacity-based top-k MoE with sort-free scatter dispatch.

    Returns (y, aux_loss).  Dropless up to ``capacity_factor``; overflow
    tokens fall through to the shared experts / residual (standard
    capacity-drop semantics).

    ``with_routing=True`` additionally returns the raw top-k expert ids
    (n_tok, k) int32 — the tiered-residency manager (serve/residency.py)
    reads them host-side to decide which experts the next step needs.
    Routing forces the global dispatch path (the local shard_map path has
    no single routing tensor to return).

    When ``p["residency"]`` is present (a per-layer ``{"slot_of_expert",
    "expert_of_slot"}`` pair of int32 maps installed by the residency
    manager), the expert stacks in ``p["experts"]`` hold only the
    HBM-cached *slots*: routed activations are gathered into slot order,
    the grouped kernel runs over the C-slot stacks, and outputs scatter
    back to expert order.  Absent experts read out-of-bounds and fill
    with exact zeros — the manager guarantees every *routed* expert is
    resident before a step commits, so those zero rows only ever multiply
    zero gates and the combine stays bitwise-equal to the fully-resident
    path.
    """
    if getattr(cfg, "moe_local_dispatch", False) and not with_routing \
            and p.get("residency") is None:
        from repro.sharding.partition import current_mesh
        axis_sizes, mesh = current_mesh()
        msize = axis_sizes.get("model", 1)
        bsize = 1
        for a in ("pod", "data"):
            bsize *= axis_sizes.get(a, 1)
        if (mesh is not None and hasattr(mesh, "devices") and msize > 1
                and cfg.n_experts % msize == 0
                and x.shape[0] % bsize == 0):
            return apply_moe_local(p, x, cfg, lut=lut, impl=impl)
        # no concrete mesh / non-divisible batch: global dispatch below
    b, t, d = x.shape
    n_tok = b * t
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(n_tok, d)

    router_logits = linear(xf, p["router"], lut, impl=impl).astype(jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)        # (n_tok, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Load-balance aux loss (Switch-style): e * Σ_e f_e · P_e.
    onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)  # (n,k,e)
    f = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
    pmean = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f * pmean)

    cap = _capacity(n_tok, k, e, cfg.capacity_factor)

    # Position of each (token, slot) within its expert queue.
    flat_e = expert_ids.reshape(-1)                        # (n·k,)
    onehot_flat = onehot.reshape(n_tok * k, e)
    pos_in_e = (jnp.cumsum(onehot_flat, axis=0) - onehot_flat)  # counts before
    slot = jnp.sum(pos_in_e * onehot_flat, axis=-1).astype(jnp.int32)  # (n·k,)
    keep = slot < cap
    slot_c = jnp.where(keep, slot, cap)                    # cap → dropped (OOB)

    # Scatter token indices into the (e, cap) dispatch table.
    tok_idx = jnp.repeat(jnp.arange(n_tok), k)
    table = jnp.full((e, cap), n_tok, jnp.int32)           # n_tok = zero row
    table = table.at[flat_e, slot_c].set(tok_idx, mode="drop")
    gtable = jnp.zeros((e, cap), jnp.float32)
    gtable = gtable.at[flat_e, slot_c].set(gate_vals.reshape(-1), mode="drop")

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xe = xpad[table]                                       # (e, cap, d)
    # EP: dispatch table and expert activations shard on the expert dim —
    # SPMD otherwise replicates the (e, cap, d) gather (60 GiB/dev at the
    # 32k prefill shape; §Perf iteration 3).  The induced collective is the
    # token all-to-all any EP implementation pays.
    xe = constrain(xe, "model", None, None)

    res = p.get("residency")
    if getattr(cfg, "moe_expert_scan", False) and res is None:
        # Paper's decompress-on-demand at *expert* granularity: scan over
        # experts, decode one expert's weights at a time — peak memory is
        # (all experts compressed) + (one expert dense), the MoE analogue
        # of the paper's layer-by-layer decompression.  Single-device edge
        # mode; under EP sharding prefer the vectorized path below (each
        # device decodes only its expert shard).
        def expert_body(_, inp):
            wg_e, wu_e, wd_e, x_e = inp
            wg_d = materialize_weight(wg_e, lut, x.dtype)
            wu_d = materialize_weight(wu_e, lut, x.dtype)
            wd_d = materialize_weight(wd_e, lut, x.dtype)
            g = x_e @ wg_d.T
            u = x_e @ wu_d.T
            return None, (jax.nn.silu(g) * u) @ wd_d.T

        _, ye = jax.lax.scan(
            expert_body, None,
            (p["experts"]["w_gate"], p["experts"]["w_up"],
             p["experts"]["w_down"], xe))
    elif res is not None:
        # Tiered residency: only the HBM-cached slots carry expert planes.
        # Gather routed activations into slot order (vacant slots — sentinel
        # index E, out of bounds — fill with zeros), run the grouped kernel
        # over the C-slot stacks, scatter back to expert order (absent
        # experts — sentinel index C — fill with zeros, multiplied below by
        # their all-zero gtable rows).  Per-expert kernel tiles see exactly
        # the bytes and activations the fully-resident stack would give
        # them, so resident rows are bitwise-identical.
        xe_c = jnp.take(xe, res["expert_of_slot"], axis=0,
                        mode="fill", fill_value=0)         # (C, cap, d)
        ye_c = _expert_ffn(p["experts"], xe_c, lut, impl)
        ye = jnp.take(ye_c, res["slot_of_expert"], axis=0,
                      mode="fill", fill_value=0)           # (e, cap, d)
    else:
        # Grouped fused expert FFN: compressed stacks stream through the
        # expert-grid megakernel (shard-mapped onto the model axis under a
        # concrete mesh) instead of materializing (E, ffe, d) dense — see
        # _expert_ffn / ops.grouped_decode_dequant_matmul.
        ye = _expert_ffn(p["experts"], xe, lut, impl)      # (e, cap, d)

    ye = constrain(ye, "model", None, None)
    out = jnp.zeros((n_tok + 1, d), x.dtype)
    out = out.at[table].add(ye * gtable[..., None].astype(x.dtype))
    y = out[:n_tok]

    if "shared" in p:
        y = y + apply_mlp(p["shared"], xf, lut=lut, impl=impl)
    y = y.reshape(b, t, d)
    if with_routing:
        return y, aux, expert_ids
    return y, aux
