"""Mamba2 / SSD (state-space duality) layers — attention-free backbone.

Implements the chunked SSD algorithm (Dao & Gu, arXiv:2405.21060): within a
chunk the quadratic "attention" form, across chunks a linear state
recurrence carried by ``lax.scan`` — O(T) total, constant-size decode state.
The recurrence parameters (A_log, dt_bias, conv, D) stay dense per the
compression policy (DESIGN.md §Arch-applicability); the big in/out
projections carry Tiny-QMoE compression like any other linear.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import linear, rms_norm


def init_mamba2(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    g = cfg.ssm_n_groups
    h = cfg.ssm_heads
    kw = cfg.ssm_conv
    conv_dim = di + 2 * g * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # in_proj emits [z(di), x(di), B(g·n), C(g·n), dt(h)]
    d_in_proj = 2 * di + 2 * g * n + h
    return {
        "in_proj": jax.random.normal(k1, (d_in_proj, d), dtype) / math.sqrt(d),
        "conv_w": jax.random.normal(k2, (conv_dim, kw), dtype) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(dtype)),
        "dt_bias": jnp.zeros((h,), dtype),
        "d_skip": jnp.ones((h,), dtype),
        "gate_norm": jnp.ones((di,), dtype),
        "out_proj": jax.random.normal(k3, (d, di), dtype) / math.sqrt(di),
    }


def init_ssm_cache(cfg, batch: int, dtype=jnp.float32):
    """Decode state: conv ring buffer + SSM state (constant in T)."""
    di = cfg.d_inner
    g, n, h = cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_head_dim
    conv_dim = di + 2 * g * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, h, p, n), jnp.float32),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv1d.  xbc: (B, T, C); w: (C, K).

    With ``state`` (B, K-1, C) prepended (decode / chunked prefill),
    returns (y, new_state).
    """
    bsz, t, c = xbc.shape
    kw = w.shape[1]
    if state is None:
        pad = jnp.zeros((bsz, kw - 1, c), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)             # (B, T+K-1, C)
    # window sum: y[t] = Σ_j x[t+j]·w[:, j]
    y = jnp.zeros((bsz, t, c), jnp.float32)
    for j in range(kw):
        y = y + xp[:, j:j + t].astype(jnp.float32) * w[:, j].astype(jnp.float32)
    y = y + b.astype(jnp.float32)
    new_state = xp[:, -(kw - 1):] if kw > 1 else jnp.zeros((bsz, 0, c), xbc.dtype)
    return jax.nn.silu(y).astype(xbc.dtype), new_state


def _segsum(x: jax.Array) -> jax.Array:
    """segsum(x)[..., i, j] = Σ_{j<k<=i} x[..., k]; -inf above diagonal."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b_in: jax.Array,
                c_in: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x:  (B, T, H, P)   inputs per head
    dt: (B, T, H)      positive step sizes (softplus applied by caller)
    a:  (H,)           negative decay rates
    b_in, c_in: (B, T, G, N) with H % G == 0
    Returns (y: (B, T, H, P), final_state: (B, H, P, N)).
    """
    bsz, t, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    rep = h // g
    nchunks = -(-t // chunk)
    pad = nchunks * chunk - t
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tt = nchunks * chunk

    # head-grouped views (expand G -> H lazily via reshape of einsum inputs)
    bh = jnp.repeat(b_in, rep, axis=2) if rep > 1 else b_in  # (B,T,H,N) via G
    ch = jnp.repeat(c_in, rep, axis=2) if rep > 1 else c_in

    def to_chunks(z, extra):
        return z.reshape((bsz, nchunks, chunk) + extra)

    xc = to_chunks(x, (h, p)).astype(jnp.float32)
    dtc = to_chunks(dt, (h,)).astype(jnp.float32)
    bc = to_chunks(bh, (h, n)).astype(jnp.float32)
    cc = to_chunks(ch, (h, n)).astype(jnp.float32)

    da = dtc * a[None, None, None, :]                     # (B,c,Q,H) ≤ 0
    da_cum = jnp.cumsum(da, axis=2)                       # within-chunk
    xdt = xc * dtc[..., None]

    # Intra-chunk (quadratic within chunk):
    lmat = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))      # (B,c,H,Q,Q)
    y_diag = jnp.einsum("bcqhn,bckhn,bchqk,bckhp->bcqhp",
                        cc, bc, lmat, xdt)

    # Chunk-final states: states[c] = Σ_k exp(da_cum[-1]-da_cum[k]) B_k xdt_k
    decay_states = jnp.exp(da_cum[:, :, -1:, :] - da_cum)  # (B,c,Q,H)
    states = jnp.einsum("bckhn,bckh,bckhp->bchpn", bc, decay_states, xdt)

    # Inter-chunk recurrence (linear scan over chunks).
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])             # (B,c,H)

    def scan_body(s_prev, inp):
        st, dec = inp                                      # (B,H,P,N), (B,H)
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev

    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((bsz, h, p, n), jnp.float32))
    final_state, prev_states = jax.lax.scan(
        scan_body, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (B,c,H,P,N)

    # Off-diagonal contribution from carried state.
    state_decay = jnp.exp(da_cum)                          # (B,c,Q,H)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                       cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(bsz, tt, h, p)[:, :t]
    return y, final_state


def ssd_decode_step(x, dt, a, b_in, c_in, state):
    """Single-token recurrent update (decode).

    x: (B, 1, H, P); dt: (B, 1, H); b_in/c_in: (B, 1, G, N);
    state: (B, H, P, N) → (y (B,1,H,P), new_state).
    """
    bsz, _, h, p = x.shape
    g = b_in.shape[2]
    rep = h // g
    bh = jnp.repeat(b_in, rep, axis=2) if rep > 1 else b_in
    ch = jnp.repeat(c_in, rep, axis=2) if rep > 1 else c_in
    da = jnp.exp(dt[:, 0, :].astype(jnp.float32) * a[None, :])   # (B,H)
    xdt = (x[:, 0] * dt[:, 0, :, None]).astype(jnp.float32)      # (B,H,P)
    upd = jnp.einsum("bhp,bhn->bhpn", xdt, bh[:, 0].astype(jnp.float32))
    s_new = state * da[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", s_new, ch[:, 0].astype(jnp.float32))
    return y[:, None], s_new


def apply_mamba2(p, x: jax.Array, cfg, *, lut=None, cache=None,
                 impl: str = "auto"):
    """Full Mamba2 block: in_proj → conv → SSD → gated norm → out_proj.

    Returns (y, new_cache).  cache=None → training/prefill-from-scratch
    (final state discarded for training, returned for prefill via cache={}).
    """
    bsz, t, d = x.shape
    di = cfg.d_inner
    g, n, h = cfg.ssm_n_groups, cfg.ssm_state, cfg.ssm_heads
    hp = cfg.ssm_head_dim

    zxbcdt = linear(x, p["in_proj"], lut, impl=impl)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * g * n]
    dt_raw = zxbcdt[..., -h:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))

    conv_state = cache.get("conv") if cache else None
    xbc_c, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs = xbc_c[..., :di].reshape(bsz, t, h, hp)
    b_in = xbc_c[..., di:di + g * n].reshape(bsz, t, g, n)
    c_in = xbc_c[..., di + g * n:].reshape(bsz, t, g, n)

    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    if cache is not None and t == 1:
        y, new_state = ssd_decode_step(xs, dt, a, b_in, c_in, cache["ssm"])
    else:
        init_state = cache.get("ssm") if cache else None
        y, new_state = ssd_chunked(xs, dt, a, b_in, c_in, cfg.ssm_chunk,
                                   init_state)

    y = y + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(bsz, t, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = linear(y, p["out_proj"], lut, impl=impl)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssm": new_state}
    return out, new_cache
