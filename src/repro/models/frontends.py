"""Modality frontend STUBS (per assignment: ``[audio]``/``[vlm]`` entries
specify the transformer backbone only; ``input_specs()`` provides
precomputed frame/patch embeddings).

These helpers generate correctly-shaped stand-ins for tests/examples and
document the real interface a production frontend would implement.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def audio_frame_embeddings(key, batch: int, n_frames: int, d_model: int,
                           dtype=jnp.float32) -> jax.Array:
    """Stand-in for a conformer/w2v-BERT audio encoder frontend output.

    Real system: 16 kHz waveform → fbank → conv subsampling → (B, S, d).
    """
    return jax.random.normal(key, (batch, n_frames, d_model), dtype) * 0.02


def vision_patch_embeddings(key, batch: int, n_patches: int, d_model: int,
                            dtype=jnp.float32) -> jax.Array:
    """Stand-in for an InternViT patch-embedding + projector output.

    Real system: 448×448 image → ViT → pixel-shuffle → MLP projector →
    (B, P, d) tokens prepended to the text sequence.
    """
    return jax.random.normal(key, (batch, n_patches, d_model), dtype) * 0.02


def frontend_spec(kind: str, batch: int, seq: int, n_patches: int,
                  d_model: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct for dry-run input_specs."""
    if kind == "audio":
        return jax.ShapeDtypeStruct((batch, seq, d_model), dtype)
    if kind == "vision":
        return jax.ShapeDtypeStruct((batch, n_patches, d_model), dtype)
    raise ValueError(kind)
