"""Model zoo: every assigned architecture family, Tiny-QMoE aware."""
from .config import ModelConfig
from . import layers, ssm, lm, encdec, frontends

__all__ = ["ModelConfig", "layers", "ssm", "lm", "encdec", "frontends"]
