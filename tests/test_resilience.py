"""Fault-injection suite — integrity checking + the serving degradation
ladder, for one dense config (llama3.2-1b) and one MoE config
(deepseek-v2-lite-16b).

Proves, with seeded faults from ``repro.testing.FaultInjector``:
  * a single bit flip in any compressed plane (codes/literals/LUT) is
    detected by ``verify_serve_state`` with the offending leaf *named*;
  * structurally-invalid planes (out-of-range LUT index) are caught by
    the device-side invariant check;
  * the ``ResilientEngine`` ladder recovers an injected in-graph
    ``JaxRuntimeError`` by falling back fused → unfused (→ materialize),
    ticking ``FALLBACK_COUNTS`` per rung;
  * transient faults recover in place via bounded retry;
  * deadlines expire as ``DeadlineExceeded``; an exhausted ladder refuses
    with per-rung diagnostics;
  * a corrupt newest checkpoint falls back to the previous committed step.
"""
import dataclasses
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import CompressionPolicy
from repro.core.integrity import (IntegrityError, check_invariants,
                                  verify_serve_state)
from repro.kernels import ops
from repro.serve import engine as engine_mod
from repro.serve import resilience
from repro.serve.engine import build_serve_params, generate
from repro.serve.resilience import (FALLBACK_COUNTS, DeadlineExceeded,
                                    ResilientEngine, ResiliencePolicy,
                                    ServeRefused)
from repro.testing import FaultInjector
from repro.train import checkpoint as ckpt

ARCHS = ["llama3.2-1b", "deepseek-v2-lite-16b"]


@pytest.fixture(scope="module", params=ARCHS)
def served(request):
    """(cfg, ServeState, tokens, reference greedy output) per arch."""
    from repro.models import lm as LM
    cfg = get_config(request.param).smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    st = build_serve_params(
        params, CompressionPolicy(mode="compressed", min_weight_size=1024))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    ref = np.asarray(generate(st.params, cfg, toks, lut=st.lut, max_new=4))
    return cfg, st, toks, ref


# -- artifact integrity ------------------------------------------------

def test_manifest_built_and_verifies(served):
    cfg, st, _, _ = served
    assert st.manifest is not None and st.manifest["leaves"]
    assert st.manifest["total_bytes"] > 0
    for level in ("fast", "full"):
        rep = verify_serve_state(st, level=level)
        assert rep.ok, rep.corrupt
        assert rep.checked > 0
    assert verify_serve_state(st, level="off").ok


def test_bitflip_in_codes_detected_and_named(served):
    cfg, st, _, _ = served
    inj = FaultInjector()
    bad, name = inj.flip_bit(st, "", plane="codes")
    rep = verify_serve_state(bad, level="full")
    assert not rep.ok
    assert name in rep.quarantined
    # the clean state still verifies (flip_bit copied)
    assert verify_serve_state(st, level="full").ok


def test_bitflip_in_literals_detected(served):
    cfg, st, _, _ = served
    inj = FaultInjector()
    bad, name = inj.flip_bit(st, "", plane="literals")
    rep = verify_serve_state(bad, level="full")
    assert not rep.ok and name in rep.quarantined


def test_lut_bitflip_detected(served):
    cfg, st, _, _ = served
    inj = FaultInjector()
    bad = inj.flip_lut_bit(st)
    rep = verify_serve_state(bad, level="full")
    assert not rep.ok
    assert any(plane == "lut" for _, plane, _ in rep.corrupt)


def test_invariant_check_catches_out_of_range_code(served):
    cfg, st, _, _ = served
    n_rows = st.lut.shape[0]
    if n_rows >= (1 << 16) - 1:
        pytest.skip("LUT fills the uint16 code space")
    flat, treedef = jax.tree_util.tree_flatten_with_path(st.params)
    leaves = [leaf for _, leaf in flat]
    idx = next(i for i, (p, _) in enumerate(flat)
               if jax.tree_util.keystr(p).endswith(".codes"))
    arr = np.asarray(jax.device_get(leaves[idx])).copy()
    arr.reshape(-1)[0] = n_rows            # indexes past the LUT, not ESCAPE
    leaves[idx] = jnp.asarray(arr)
    bad = dataclasses.replace(st, params=treedef.unflatten(leaves))
    rep = check_invariants(bad)
    assert not rep.ok and rep.quarantined
    assert check_invariants(st).ok


def test_engine_integrity_gate_refuses_corrupt_artifact(served):
    cfg, st, _, _ = served
    inj = FaultInjector()
    bad, name = inj.flip_bit(st, "", plane="codes")
    with pytest.raises(IntegrityError) as ei:
        ResilientEngine(cfg, bad, policy=ResiliencePolicy(verify="full"))
    assert name in ei.value.report.quarantined
    assert FALLBACK_COUNTS["integrity_refused"] == 1


# -- degradation ladder ------------------------------------------------

def test_ladder_falls_back_to_unfused_on_ingraph_fault(served):
    """A persistent fault inside the fused decode kernel's jitted program
    surfaces as JaxRuntimeError; the ladder re-traces on the unfused rung
    and returns output identical to the clean fused run."""
    cfg, st, toks, ref = served
    cfgf = dataclasses.replace(cfg, name=cfg.name + "-rl-ladder")
    eng = ResilientEngine(cfgf, st,
                          policy=ResiliencePolicy(max_retries=0,
                                                  verify="fast"))
    inj = FaultInjector()
    ops.DISPATCH_COUNTS.clear()
    with inj.decode_fault(nth=1):
        out = eng.generate(toks, max_new=4)
    np.testing.assert_array_equal(np.asarray(out), ref)
    assert eng.last_rung == "unfused"
    assert FALLBACK_COUNTS["unfused"] == 1
    assert "materialize" not in FALLBACK_COUNTS
    assert any(k.startswith("unfused") or k.startswith("tiled_unfused")
               or k.startswith("grouped_unfused")
               for k in ops.DISPATCH_COUNTS)
    h = eng.health()
    assert h["last_rung"] == "unfused" and h["recent_errors"]


def test_ladder_walks_every_rung_then_succeeds(served):
    """Seam faults on the first two rungs push the request down to
    materialize; FALLBACK_COUNTS records each rung entry."""
    cfg, st, toks, ref = served
    cfgf = dataclasses.replace(cfg, name=cfg.name + "-rl-allrungs")
    eng = ResilientEngine(cfgf, st,
                          policy=ResiliencePolicy(max_retries=0))
    inj = FaultInjector()
    orig = resilience._generate
    resilience._generate = inj.failing(orig, times=2)
    try:
        out = eng.generate(toks, max_new=4)
    finally:
        resilience._generate = orig
    assert np.asarray(out).shape == ref.shape
    assert eng.last_rung == "materialize"
    assert FALLBACK_COUNTS["unfused"] == 1
    assert FALLBACK_COUNTS["materialize"] == 1
    assert len(eng.health()["recent_errors"]) == 2


def test_transient_fault_recovers_by_retry(served):
    """One-shot fault at the request seam: bounded retry recovers on the
    fused rung itself — no fallback, output equals the clean run."""
    cfg, st, toks, ref = served
    eng = ResilientEngine(cfg, st, policy=ResiliencePolicy(max_retries=1))
    inj = FaultInjector()
    orig = resilience._generate
    resilience._generate = inj.failing(orig, times=1)
    try:
        out = eng.generate(toks, max_new=4)
    finally:
        resilience._generate = orig
    np.testing.assert_array_equal(np.asarray(out), ref)
    assert eng.last_rung == "fused"
    assert FALLBACK_COUNTS["retry:fused"] == 1
    assert "unfused" not in FALLBACK_COUNTS


def test_ladder_exhausted_refuses_with_diagnostics(served):
    cfg, st, toks, _ = served
    eng = ResilientEngine(
        cfg, st, policy=ResiliencePolicy(max_retries=1, ladder=("fused",)))
    inj = FaultInjector()
    orig = resilience._generate
    resilience._generate = inj.failing(orig, times=10)
    try:
        with pytest.raises(ServeRefused) as ei:
            eng.generate(toks, max_new=4)
    finally:
        resilience._generate = orig
    assert FALLBACK_COUNTS["refused"] == 1
    assert FALLBACK_COUNTS["retry:fused"] == 1
    assert len(ei.value.errors) == 2          # 1 try + 1 retry, one rung
    assert all(r == "fused" for r, _, _ in ei.value.errors)


def test_deadline_expires_mid_ladder(served):
    cfg, st, toks, _ = served
    eng = ResilientEngine(
        cfg, st, policy=ResiliencePolicy(max_retries=3, deadline_s=0.05))
    inj = FaultInjector()

    def slow_fail(*a, **kw):
        time.sleep(0.06)
        raise jax.errors.JaxRuntimeError("injected slow fault")

    orig = resilience._generate
    resilience._generate = slow_fail
    try:
        with pytest.raises(DeadlineExceeded):
            eng.generate(toks, max_new=4)
    finally:
        resilience._generate = orig
    assert FALLBACK_COUNTS["deadline"] == 1
    assert FALLBACK_COUNTS["refused"] == 0


# -- checkpoint damage -------------------------------------------------

def _tiny_tree():
    return {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.ones((3,), jnp.float32)}


def test_restore_latest_falls_back_past_truncated_step(tmp_path):
    d = str(tmp_path / "ck")
    tree = _tiny_tree()
    ckpt.save(d, 3, tree)
    ckpt.save(d, 9, jax.tree_util.tree_map(lambda x: x * 2, tree))
    inj = FaultInjector()
    inj.truncate_step(d, 9)                   # unreadable archive
    skipped = []
    state, step = ckpt.restore_latest(
        d, jax.tree_util.tree_map(jnp.zeros_like, tree),
        on_skip=lambda s, e: skipped.append(s))
    assert step == 3 and skipped == [9]
    np.testing.assert_array_equal(np.asarray(state["w"]),
                                  np.asarray(tree["w"]))


def test_restore_latest_falls_back_past_bitrot(tmp_path):
    """Readable archive, flipped payload bits — only the checksum layer
    catches this one."""
    d = str(tmp_path / "ck")
    tree = _tiny_tree()
    ckpt.save(d, 1, tree)
    ckpt.save(d, 2, jax.tree_util.tree_map(lambda x: x + 1, tree))
    inj = FaultInjector()
    inj.corrupt_step(d, 2, nbits=32)
    state, step = ckpt.restore_latest(
        d, jax.tree_util.tree_map(jnp.zeros_like, tree))
    assert step == 1
    np.testing.assert_array_equal(np.asarray(state["b"]),
                                  np.asarray(tree["b"]))


def test_restore_latest_skips_uncommitted_newest(tmp_path):
    d = str(tmp_path / "ck")
    tree = _tiny_tree()
    ckpt.save(d, 5, tree)
    ckpt.save(d, 8, tree)
    FaultInjector().uncommit_step(d, 8)  # torn write
    _, step = ckpt.restore_latest(
        d, jax.tree_util.tree_map(jnp.zeros_like, tree))
    assert step == 5


def test_restore_latest_raises_when_nothing_loadable(tmp_path):
    d = str(tmp_path / "ck")
    tree = _tiny_tree()
    ckpt.save(d, 4, tree)
    FaultInjector().truncate_step(d, 4)
    with pytest.raises(FileNotFoundError):
        ckpt.restore_latest(d, jax.tree_util.tree_map(jnp.zeros_like, tree))


# -- tiered residency under fault --------------------------------------

def test_fetch_fault_miss_storm_refuses_never_hangs(served):
    """A dead host→HBM transfer link under tiered residency turns every
    cache miss into a ladder-walked fault: the miss-storm must surface
    as a refused request (quarantine → finished='refused') within a
    bounded drain — never a hang or an unaccounted drop."""
    cfg, st, _, _ = served
    if cfg.family != "moe":
        pytest.skip("tiered residency backs MoE expert planes only")
    from repro.serve.residency import RESIDENCY_COUNTS, ResidencyManager
    from repro.serve.scheduler import Request
    mgr = ResidencyManager(st, cfg, capacity=1, prefetch=False)
    reng = ResilientEngine(cfg, st, residency=mgr)
    eng = reng.scheduler(n_slots=2, max_len=24, page_size=8)
    toks = np.arange(1, 7, dtype=np.int32) % cfg.vocab_size
    with FaultInjector().fetch_fault(times=1 << 30) as probe:
        eng.submit(Request(tokens=toks, max_new=4, rid=0))
        done = eng.drain(max_steps=500)
    assert done and all(c.finished == "refused" for c in done)
    assert probe.executions > 0
    assert FALLBACK_COUNTS["refused"] >= 1


def test_fetch_fault_transient_recovers_bitwise(served):
    """A transient transfer fault (first fetch only) retries up the
    ladder and the request still completes bitwise-equal to the
    fully-resident reference — fetch faults are recoverable faults,
    not corruption."""
    cfg, st, toks, ref = served
    if cfg.family != "moe":
        pytest.skip("tiered residency backs MoE expert planes only")
    from repro.serve.residency import ResidencyManager
    mgr = ResidencyManager(st, cfg, capacity=cfg.n_experts, prefetch=False)
    reng = ResilientEngine(cfg, st, residency=mgr)
    with FaultInjector().fetch_fault(times=1) as probe:
        out = np.asarray(reng.generate(toks, max_new=4))
    assert probe.executions == 1
    assert np.array_equal(out, ref)
