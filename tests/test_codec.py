"""Dictionary codec tests — paper Listings 2-4 + the TPU blocked format."""
import numpy as np
import pytest
import jax.numpy as jnp
pytest.importorskip("hypothesis")  # property tests; skip when absent
from hypothesis import given, settings, strategies as st

from repro.core import codec, blocked_codec, lzw
from repro.core.codec import ESCAPE


def _compressible(rng, n, alphabet=16, run=8):
    """Byte stream with repeated runs (models the int8 weight streams)."""
    pats = rng.integers(0, alphabet, size=(32, run)).astype(np.uint8)
    picks = rng.integers(0, 32, size=n // run + 1)
    return np.concatenate([pats[p] for p in picks])[:n]


# ---------------------------------------------------------------------------
# Paper-faithful escape-stream codec.
# ---------------------------------------------------------------------------

def test_roundtrip_exact(rng):
    w = _compressible(rng, 10_000)
    table = codec.find_frequent_sequences([w])
    stream = codec.compress_array(w, table)
    out = codec.decompress_array(stream, table, len(w))
    np.testing.assert_array_equal(out, w)


def test_roundtrip_incompressible(rng):
    w = rng.integers(0, 256, size=4096).astype(np.uint8)
    table = codec.find_frequent_sequences([w], min_count=3)
    stream = codec.compress_array(w, table)
    out = codec.decompress_array(stream, table, len(w))
    np.testing.assert_array_equal(out, w)


def test_tail_handling(rng):
    """Length not divisible by seq_len → trailing escape (paper Listing 3)."""
    w = _compressible(rng, 1003)  # 1003 % 4 == 3
    table = codec.find_frequent_sequences([w])
    stream = codec.compress_array(w, table)
    out = codec.decompress_array(stream, table, len(w))
    np.testing.assert_array_equal(out, w)


def test_escape_stream_format(rng):
    """Unknown grams appear as ESCAPE + 4 raw values (paper's layout)."""
    w = np.arange(8, dtype=np.uint8) + 100   # unique grams, empty table
    stream = codec.compress_array(w, {})
    assert list(stream[:5]) == [ESCAPE, 100, 101, 102, 103]


def test_compression_ratio_on_structured_data(rng):
    w = _compressible(rng, 200_000)
    table, streams = codec.compress_model_arrays({"w": w})
    stats = codec.compression_ratio({"w": w}, streams, table)
    # fp16 original = 2 B/weight; structured stream compresses far below
    assert stats["ratio_vs_original"] > 3.0
    assert stats["ratio_vs_quantized"] > 1.5


def test_table_codes_dense_and_bounded(rng):
    w = _compressible(rng, 50_000)
    table = codec.find_frequent_sequences([w], max_codes=100)
    assert len(table) <= 100
    assert set(table.values()) == set(range(len(table)))
    assert max(table.values(), default=0) < ESCAPE


@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 600), alphabet=st.integers(1, 255),
       seed=st.integers(0, 2**16))
def test_property_roundtrip(n, alphabet, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(0, alphabet, size=n).astype(np.uint8)
    table = codec.find_frequent_sequences([w], min_count=2)
    stream = codec.compress_array(w, table)
    out = codec.decompress_array(stream, table, n)
    np.testing.assert_array_equal(out, w)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_compressed_not_larger_than_escape_everything(seed):
    """Stream never exceeds the all-escape worst case (5 uint16 per gram)."""
    rng = np.random.default_rng(seed)
    w = _compressible(rng, 4096)
    table = codec.find_frequent_sequences([w])
    stream = codec.compress_array(w, table)
    worst = (len(w) // 4) * 5 + 5
    assert len(stream) <= worst


# ---------------------------------------------------------------------------
# Blocked (TPU) codec — must agree with the paper codec bit-for-bit.
# ---------------------------------------------------------------------------

def test_blocked_roundtrip_exact(rng):
    w = _compressible(rng, 64 * 1024).reshape(256, 256)
    table = codec.find_frequent_sequences([w])
    bc = blocked_codec.encode_blocked(w, table, block_weights=4096)
    out = np.asarray(blocked_codec.decode_blocked_jnp(bc))
    np.testing.assert_array_equal(out, w.reshape(-1))


def test_blocked_nonaligned_length(rng):
    w = _compressible(rng, 5000)   # pads to block multiple internally
    table = codec.find_frequent_sequences([w])
    bc = blocked_codec.encode_blocked(w, table, block_weights=1024)
    out = np.asarray(blocked_codec.decode_blocked_jnp(bc))
    np.testing.assert_array_equal(out, w)


def test_blocked_same_dictionary_as_paper_codec(rng):
    """Blocked format uses the identical table; per-gram hit pattern must
    match the escape-stream codec's."""
    w = _compressible(rng, 8192)
    table = codec.find_frequent_sequences([w])
    bc = blocked_codec.encode_blocked(w, table, block_weights=1024)
    # count escapes in the paper stream
    stream = codec.compress_array(w, table)
    n_esc_paper = int((stream == ESCAPE).sum())
    n_esc_blocked = int(np.asarray(bc.nlit).sum())
    assert n_esc_blocked == n_esc_paper


def test_blocked_payload_accounting(rng):
    w = _compressible(rng, 16 * 4096)
    table = codec.find_frequent_sequences([w])
    bc = blocked_codec.encode_blocked(w, table)
    nb = bc.codes.shape[0]
    assert bc.payload_nbytes == bc.codes.size * 2 + bc.literals.size + nb * 4


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(8, 3000),
       bw=st.sampled_from([64, 256, 1024]))
def test_property_blocked_roundtrip(seed, n, bw):
    rng = np.random.default_rng(seed)
    w = _compressible(rng, n)
    table = codec.find_frequent_sequences([w], min_count=2)
    bc = blocked_codec.encode_blocked(w, table, block_weights=bw)
    out = np.asarray(blocked_codec.decode_blocked_jnp(bc))
    np.testing.assert_array_equal(out, w)


def test_shard_aligned_block_weights():
    f = blocked_codec.shard_aligned_block_weights
    assert f(16384, 16) % 4 == 0
    assert 16384 // 16 % f(16384, 16) == 0     # blocks align to TP shards
    assert f(100, 16) >= 4                      # never below seq_len


def test_decode_to_dequantizes(rng):
    w = _compressible(rng, 4096).reshape(64, 64)
    table = codec.find_frequent_sequences([w])
    bc = blocked_codec.encode_blocked(w, table, block_weights=1024)
    scale = jnp.full((64, 1), 0.5, jnp.float32)
    zero = jnp.full((64, 1), 128.0, jnp.float32)
    x = blocked_codec.decode_to(bc, scale, zero, dtype=jnp.float32)
    expect = (w.astype(np.float32) - 128.0) * 0.5
    np.testing.assert_allclose(np.asarray(x), expect, rtol=1e-6)


# ---------------------------------------------------------------------------
# LZW baseline (paper §2.2 describes LZW; the shipped algorithm is the
# fixed-gram table — we keep real LZW as a comparison baseline).
# ---------------------------------------------------------------------------

def test_lzw_roundtrip(rng):
    w = _compressible(rng, 20_000)
    enc = lzw.lzw_encode(w)
    dec = lzw.lzw_decode(enc, len(w))
    np.testing.assert_array_equal(dec, w)


def test_lzw_compresses_structured(rng):
    w = _compressible(rng, 50_000)
    assert lzw.lzw_ratio(w) > 2.0


# ---------------------------------------------------------------------------
# TiledPackedLinear (2D-TP compressed storage, §Perf D2)
# ---------------------------------------------------------------------------

def test_tiled_pack_matches_untiled(rng):
    import jax.numpy as jnp
    from repro.core.compressed import (pack_linear, pack_linear_tiled,
                                       quantize_linear)
    from repro.core.blocked_codec import build_lut
    w = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32))
    ql = quantize_linear(w)
    table = codec.find_frequent_sequences([np.asarray(ql.values)])
    lut = build_lut(table)
    packed = pack_linear(w, table, lut, block_weights=512)
    tiled = pack_linear_tiled(w, table, lut, tiles=4, block_weights=512)
    lutj = jnp.asarray(lut)
    np.testing.assert_array_equal(
        np.asarray(tiled.materialize_int8(lutj)),
        np.asarray(packed.materialize_int8(lutj)))
    np.testing.assert_allclose(
        np.asarray(tiled.materialize(lutj, jnp.float32)),
        np.asarray(packed.materialize(lutj, jnp.float32)), rtol=1e-6)


def test_tiled_planned_specs_match_builder(rng):
    import jax
    import jax.numpy as jnp
    from repro.core.compressed import (pack_linear_tiled, planned_tiled_specs,
                                       quantize_linear)
    from repro.core.blocked_codec import build_lut
    w = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32))
    ql = quantize_linear(w)
    table = codec.find_frequent_sequences([np.asarray(ql.values)])
    lut = build_lut(table)
    real = pack_linear_tiled(w, table, lut, tiles=4, block_weights=256)
    spec = planned_tiled_specs((32, 64), 4, block_weights=256)
    assert real.codes.shape == spec.codes.shape
    assert real.nlit.shape == spec.nlit.shape
    assert real.scale.shape == spec.scale.shape


def test_tiled_linear_matches_dense(rng):
    import jax.numpy as jnp
    from repro.core.compressed import pack_linear_tiled, quantize_linear
    from repro.core.blocked_codec import build_lut
    from repro.models.layers import linear
    w = jnp.asarray(rng.normal(size=(48, 64)).astype(np.float32))
    ql = quantize_linear(w)
    table = codec.find_frequent_sequences([np.asarray(ql.values)])
    lut = build_lut(table)
    tiled = pack_linear_tiled(w, table, lut, tiles=4, block_weights=256)
    x = jnp.asarray(rng.normal(size=(2, 5, 64)).astype(np.float32))
    y_tiled = linear(x, tiled, jnp.asarray(lut))
    w_deq = (ql.values.astype(np.float32) - np.asarray(ql.zero)) * \
        np.asarray(ql.scale)
    y_ref = np.asarray(x) @ w_deq.T
    np.testing.assert_allclose(np.asarray(y_tiled), y_ref,
                               rtol=2e-3, atol=2e-3)
