"""Checkpoint/restart + fault tolerance + elastic restore."""
import os
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm as LM
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, DataPipeline
from repro.train.fault import FaultConfig, FaultTolerantLoop, elastic_restore
from repro.train.steps import TrainConfig, make_train_step, init_train_state


def _state():
    cfg = get_config("llama3.2-1b").smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    tcfg = TrainConfig()
    return cfg, tcfg, init_train_state(params, tcfg)


def test_save_restore_roundtrip(tmp_path):
    cfg, tcfg, state = _state()
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, state)
    assert ckpt.latest_step(d) == 7
    zeroed = jax.tree_util.tree_map(jnp.zeros_like, state)
    restored = ckpt.restore(d, 7, zeroed)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoint_skipped(tmp_path):
    cfg, tcfg, state = _state()
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, state)
    ckpt.save(d, 9, state)
    os.remove(os.path.join(d, "step_00000009", ckpt.COMMIT))  # torn write
    assert ckpt.latest_step(d) == 3


def test_prune_keeps_newest(tmp_path):
    cfg, tcfg, state = _state()
    d = str(tmp_path / "ck")
    small = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, small)
    ckpt.prune_old(d, keep=2)
    steps = sorted(int(p.split("_")[1]) for p in os.listdir(d)
                   if p.startswith("step_"))
    assert steps == [4, 5]


def test_restore_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"x": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ckpt.restore(d, 1, {"x": jnp.zeros((5,))})


def test_fault_loop_resume(tmp_path):
    """Kill after N steps; a fresh loop resumes from the last commit and
    reproduces the exact same final state as an uninterrupted run."""
    cfg, tcfg, state0 = _state()
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size, batch=4,
                                   seq_len=8, seed=5))
    step = jax.jit(make_train_step(cfg, tcfg))
    d = str(tmp_path / "ck")

    # uninterrupted reference: 6 steps
    ref = state0
    for i in range(6):
        ref, _ = step(ref, data.batch_at(i))

    # interrupted: run 4 (ckpt_every=2 → commit at 2,4), "crash", resume to 6
    fcfg = FaultConfig(ckpt_dir=d, ckpt_every=2, handle_sigterm=False)
    loop = FaultTolerantLoop(step, state0, data, fcfg)
    loop.run(4)
    loop2 = FaultTolerantLoop(step, state0, data, fcfg)
    start = loop2.maybe_resume()
    assert start == 4
    final = loop2.run(6)

    for a, b in zip(jax.tree_util.tree_leaves(ref["params"]),
                    jax.tree_util.tree_leaves(final["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_elastic_restore_new_mesh(tmp_path):
    """Restore onto a different mesh topology (single-device container:
    (1,1) mesh stands in for the survivor topology)."""
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import partition as PT
    cfg, tcfg, state = _state()
    d = str(tmp_path / "ck")
    ckpt.save(d, 11, state)

    mesh = make_host_mesh()

    def make_shardings(like, m):
        specs = PT.make_train_state_specs(like, m)
        return PT.to_named(specs, m)

    restored, step_no = elastic_restore(d, state, mesh, make_shardings)
    assert step_no == 11
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fault_loop_straggler_flag(tmp_path):
    cfg, tcfg, state = _state()
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size, batch=2,
                                   seq_len=8))
    step = jax.jit(make_train_step(cfg, tcfg))
    seen = []
    fcfg = FaultConfig(ckpt_dir=str(tmp_path / "ck"), ckpt_every=100,
                       step_timeout_s=1e-9, handle_sigterm=False)
    loop = FaultTolerantLoop(step, state, data, fcfg,
                             on_metrics=lambda s, m: seen.append(m))
    loop.run(2)
    assert any(m.get("straggler") for m in seen)


def test_preemption_guard_flags_sigterm_and_sigint():
    """Both preemption signals (scheduler SIGTERM, operator SIGINT) set the
    flag without killing the process; restore() reinstates the previous
    handlers so scoped guards don't leak."""
    import signal
    from repro.train.fault import PreemptionGuard

    before = {s: signal.getsignal(s) for s in PreemptionGuard.SIGNALS}
    guard = PreemptionGuard()
    try:
        assert not guard.fired
        signal.raise_signal(signal.SIGTERM)
        assert guard.fired
        guard.fired = False
        signal.raise_signal(signal.SIGINT)   # no KeyboardInterrupt raised
        assert guard.fired
    finally:
        guard.restore()
    for s in PreemptionGuard.SIGNALS:
        assert signal.getsignal(s) is before[s]


def test_preemption_guard_triggers_checkpoint(tmp_path):
    """A signal mid-run makes the loop commit and stop at the next step
    boundary — the resume then picks up from that commit."""
    import signal
    cfg, tcfg, state = _state()
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size, batch=2,
                                   seq_len=8))
    step = jax.jit(make_train_step(cfg, tcfg))
    d = str(tmp_path / "ck")
    fcfg = FaultConfig(ckpt_dir=d, ckpt_every=100)
    loop = FaultTolerantLoop(step, state, data, fcfg)

    fired_at = []

    def on_metrics(s, m):
        if s == 2 and not fired_at:
            fired_at.append(s)
            signal.raise_signal(signal.SIGINT)

    loop.on_metrics = on_metrics
    try:
        loop.run(10)
    finally:
        loop.guard.restore()
    assert fired_at == [2]
    assert ckpt.latest_step(d) == 2          # stopped + committed early
