"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the single CPU
device by default (CI's tier1-multidevice job exports
XLA_FLAGS=--xla_force_host_platform_device_count=8 itself); only
launch/dryrun.py forces 512 placeholder devices.

REPRO_TEST_IMPL=pallas_interpret re-points every ``impl='auto'`` kernel
dispatch at the Pallas kernel bodies in interpret mode (CI's
kernel-interpret job runs tests/test_kernels.py + tests/test_fused_kernel.py
this way, so the kernels — not just the jnp oracles — are validated on
every PR).
"""
import os

import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _reset_probe_counters():
    """The trace-time probes (``ops.DISPATCH_COUNTS``,
    ``engine.TRACE_COUNTS``, ``layers.MATERIALIZE_COUNTS``,
    ``resilience.FALLBACK_COUNTS``, ``residency.RESIDENCY_COUNTS``) are
    global Counters asserted by tests; reset them between tests so probe
    assertions can't leak across modules (a prior test's traces otherwise
    satisfy — or break — a later test's expectations)."""
    from repro.kernels import ops
    from repro.models import layers
    from repro.serve import engine, residency, resilience
    for counter in (ops.DISPATCH_COUNTS, engine.TRACE_COUNTS,
                    layers.MATERIALIZE_COUNTS, resilience.FALLBACK_COUNTS,
                    residency.RESIDENCY_COUNTS):
        counter.clear()
    yield


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
    impl = os.environ.get("REPRO_TEST_IMPL")
    if impl:
        from repro.kernels import ops
        ops.set_default_impl(impl)
