"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the single CPU
device; only launch/dryrun.py forces 512 placeholder devices."""
import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
