"""Shard-mapped fused decode→dequant→matmul parity + dispatch probes.

The acceptance contract of the sharded fused paths: under 1×1, 2×4 and
8×1 (data, model) meshes, ``ops.decode_dequant_matmul`` and
``ops.tiled_decode_dequant_matmul`` must (a) dispatch to the fused /
shard-mapped-fused path — asserted via the trace-time
``ops.DISPATCH_COUNTS`` probe, so a silent fall-back to the
dense-materializing two-step path fails the test — and (b) match the
unfused two-step baseline numerically.  Shapes include a prime M (131)
that forces the kernel-facing M-tile padding.  Multi-device meshes run in
a subprocess (XLA locks the device count at first init), mirroring
tests/test_sharding.py.
"""
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import codec
from repro.core.blocked_codec import build_lut, choose_fused_tiles
from repro.core.compressed import (pack_linear, pack_linear_tiled,
                                   quantize_linear)
from repro.kernels import ops


def _packed(rng, n, k, msize=1, tiles=0):
    w = jnp.asarray(rng.laplace(0.0, 0.02, size=(n, k)).astype(np.float32))
    ql = quantize_linear(w)
    table = codec.find_frequent_sequences([np.asarray(ql.values)])
    lut = build_lut(table)
    if tiles:
        p = pack_linear_tiled(w, table, lut, tiles=tiles, tile="auto",
                              shards=(msize, 1))
    else:
        picked = choose_fused_tiles((n, k), shards=(msize, 1))
        p = pack_linear(w, table, lut, tile=picked[:2] if picked else None)
    return p, jnp.asarray(lut)


def test_dispatch_probe_single_device(rng):
    """No mesh → 'fused' / 'tiled_fused'; impl='unfused' → the two-step
    probes.  (Counters tick at trace time, once per jit trace.)"""
    p, lut = _packed(rng, 32, 128)
    pt, lutt = _packed(rng, 32, 128, tiles=4)
    x = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
    ops.DISPATCH_COUNTS.clear()
    ops.decode_dequant_matmul(x, p, lut, impl="ref")
    ops.decode_dequant_matmul(x, p, lut, impl="unfused")
    ops.tiled_decode_dequant_matmul(x, pt, lutt, impl="ref")
    ops.tiled_decode_dequant_matmul(x, pt, lutt, impl="unfused")
    c = ops.DISPATCH_COUNTS
    assert c["fused"] == 1 and c["unfused"] == 1, dict(c)
    assert c["tiled_fused"] == 1 and c["tiled_unfused"] == 1, dict(c)


def test_tiled_fused_single_device_matches_two_step(rng):
    """Grouped fused call over the whole column-tile stack ≈ the dense
    materialize+einsum path (f32 oracle on CPU)."""
    pt, lut = _packed(rng, 64, 256, tiles=4)
    assert pt.tile_n > 0
    x = jnp.asarray(rng.normal(size=(131, 256)).astype(np.float32))  # prime M
    y_f = ops.tiled_decode_dequant_matmul(x, pt, lut, impl="ref",
                                          out_dtype=jnp.float32)
    y_u = ops.tiled_decode_dequant_matmul(x, pt, lut, impl="unfused",
                                          out_dtype=jnp.float32)
    err = float(jnp.abs(y_f - y_u).max() / (jnp.abs(y_u).max() + 1e-9))
    assert err < 1e-4, err


def test_shard_aware_tile_choice_divides_per_shard_dims():
    tn, tk, _ = choose_fused_tiles((1024, 4096), shards=(8, 1))
    assert (1024 // 8) % tn == 0 and 4096 % tk == 0
    # shard count that doesn't divide the dim is ignored, not fatal
    assert choose_fused_tiles((70, 96), shards=(8, 1)) == \
        choose_fused_tiles((70, 96))


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.core import codec
from repro.core.blocked_codec import build_lut, choose_fused_tiles
from repro.core.compressed import pack_linear, pack_linear_tiled, quantize_linear
from repro.kernels import ops
from repro.sharding import partition as PT

rng = np.random.default_rng(0)

def build(n, k, msize):
    w = jnp.asarray(rng.laplace(0.0, 0.02, size=(n, k)).astype(np.float32))
    ql = quantize_linear(w)
    table = codec.find_frequent_sequences([np.asarray(ql.values)])
    lut = build_lut(table)
    picked = choose_fused_tiles((n, k), shards=(msize, 1))
    return w, pack_linear(w, table, lut, tile=picked[:2]), table, lut

def relerr(a, b):
    return float(jnp.abs(a - b).max() / (jnp.abs(b).max() + 1e-9))

for mesh_shape in ((1, 1), (2, 4), (8, 1)):
    dsz, msz = mesh_shape
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    single = dsz * msz == 1
    # m=131: prime, > DEFAULT_BM once padded -> exercises the M-tile padding
    for (m, n, k) in ((16, 64, 128), (131, 64, 256)):
        w, packed, table, lut_np = build(n, k, msz)
        lut = jnp.asarray(lut_np)
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        with mesh, PT.active_mesh(mesh):
            ops.DISPATCH_COUNTS.clear()
            y_f = jax.jit(lambda x, p: ops.decode_dequant_matmul(
                x, p, lut, out_dtype=jnp.float32))(x, packed)
            y_u = jax.jit(lambda x, p: ops.decode_dequant_matmul(
                x, p, lut, impl="unfused", out_dtype=jnp.float32))(x, packed)
        want = "fused" if single else "fused_shard_map"
        assert ops.DISPATCH_COUNTS[want] >= 1, (mesh_shape, dict(ops.DISPATCH_COUNTS))
        assert relerr(y_f, y_u) < 1e-4, (mesh_shape, (m, n, k), relerr(y_f, y_u))

        # row_parallel container: same fused path, same numbers
        rp = dataclasses.replace(packed, row_parallel=True)
        with mesh, PT.active_mesh(mesh):
            y_rp = jax.jit(lambda x, p: ops.decode_dequant_matmul(
                x, p, lut, out_dtype=jnp.float32))(x, rp)
        np.testing.assert_allclose(np.asarray(y_rp), np.asarray(y_f),
                                   rtol=1e-6, atol=1e-6)

        # TiledPackedLinear 2D-TP: tiles on data, block axis on model,
        # row-parallel psum over data in the epilogue
        tiled = pack_linear_tiled(w, table, lut_np, tiles=8, tile="auto",
                                  shards=(msz, 1))
        assert tiled.tile_n > 0
        with mesh, PT.active_mesh(mesh):
            ops.DISPATCH_COUNTS.clear()
            y_tf = jax.jit(lambda x, p: ops.tiled_decode_dequant_matmul(
                x, p, lut, out_dtype=jnp.float32))(x, tiled)
            y_tu = jax.jit(lambda x, p: ops.tiled_decode_dequant_matmul(
                x, p, lut, impl="unfused", out_dtype=jnp.float32))(x, tiled)
        want = "tiled_fused" if single else "tiled_fused_shard_map"
        assert ops.DISPATCH_COUNTS[want] >= 1, (mesh_shape, dict(ops.DISPATCH_COUNTS))
        assert relerr(y_tf, y_tu) < 1e-4, (mesh_shape, (m, n, k), relerr(y_tf, y_tu))

# out-tile count that does NOT divide the weight axes -> graceful two-step
# fallback (probe proves it), numerics still exact
mesh = jax.make_mesh((2, 4), ("data", "model"))
w, packed, table, lut_np = build(64, 128, 1)   # tile_n=64 -> nnt=1, 1 % 4 != 0
lut = jnp.asarray(lut_np)
assert (64 // packed.tile_n) % 4 != 0
x = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
with mesh, PT.active_mesh(mesh):
    ops.DISPATCH_COUNTS.clear()
    y_f = jax.jit(lambda x, p: ops.decode_dequant_matmul(
        x, p, lut, out_dtype=jnp.float32))(x, packed)
    y_u = jax.jit(lambda x, p: ops.decode_dequant_matmul(
        x, p, lut, impl="unfused", out_dtype=jnp.float32))(x, packed)
assert ops.DISPATCH_COUNTS["fused_shard_map"] == 0, dict(ops.DISPATCH_COUNTS)
assert ops.DISPATCH_COUNTS["unfused"] >= 1, dict(ops.DISPATCH_COUNTS)
assert relerr(y_f, y_u) < 1e-5

print("SHARDED_FUSED_OK")
"""


@pytest.mark.slow
def test_sharded_fused_parity_subprocess():
    """1×1, 2×4, 8×1 meshes: fused/shard-mapped dispatch + parity vs the
    unfused baseline, for PackedLinear and TiledPackedLinear."""
    r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "SHARDED_FUSED_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-2000:]


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (tier1-multidevice CI job)")
def test_sharded_fused_inprocess_8dev(rng):
    """Direct (non-subprocess) version for the multi-device CI job: the
    2×4 mesh must take both shard-mapped fused paths and match unfused."""
    from repro.sharding import partition as PT
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    p, lut = _packed(rng, 64, 256, msize=4)
    pt, lutt = _packed(rng, 64, 256, msize=4, tiles=8)
    x = jnp.asarray(rng.normal(size=(16, 256)).astype(np.float32))
    with mesh, PT.active_mesh(mesh):
        ops.DISPATCH_COUNTS.clear()
        y_f = jax.jit(lambda x, p: ops.decode_dequant_matmul(
            x, p, lut, out_dtype=jnp.float32))(x, p)
        y_u = jax.jit(lambda x, p: ops.decode_dequant_matmul(
            x, p, lut, impl="unfused", out_dtype=jnp.float32))(x, p)
        y_tf = jax.jit(lambda x, p: ops.tiled_decode_dequant_matmul(
            x, p, lutt, out_dtype=jnp.float32))(x, pt)
        y_tu = jax.jit(lambda x, p: ops.tiled_decode_dequant_matmul(
            x, p, lutt, impl="unfused", out_dtype=jnp.float32))(x, pt)
    c = ops.DISPATCH_COUNTS
    assert c["fused_shard_map"] >= 1 and c["tiled_fused_shard_map"] >= 1, \
        dict(c)
    for got, ref_ in ((y_f, y_u), (y_tf, y_tu)):
        err = float(jnp.abs(got - ref_).max() / (jnp.abs(ref_).max() + 1e-9))
        assert err < 1e-4, err
