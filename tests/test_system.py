"""End-to-end system tests — the paper's full pipeline + framework glue."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config, ASSIGNED_ARCHS
from repro.core import CompressionPolicy
from repro.launch import hlo_stats
from repro.models import lm as LM
from repro.serve.engine import build_serve_params, generate
from repro.train.data import DataConfig, DataPipeline
from repro.train.optimizer import AdamWConfig
from repro.train.steps import TrainConfig, make_train_step, init_train_state


@pytest.mark.slow
def test_paper_pipeline_end_to_end():
    """Train → quantize → compress → serve → verify parity: the whole
    Tiny-QMoE story on one tiny model."""
    cfg = get_config("llama3.2-1b").smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size, batch=16,
                                   seq_len=32))
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-2, warmup_steps=10,
                                             total_steps=100))
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    first = last = None
    for i in range(60):
        state, m = step(state, data.batch_at(i))
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first  # learned something
    params = state["params"]

    sq = build_serve_params(params, CompressionPolicy(mode="quant",
                                                      min_weight_size=1024))
    sc = build_serve_params(params, CompressionPolicy(mode="compressed",
                                                      min_weight_size=1024))
    prompt = jnp.asarray(np.asarray(data.batch_at(77)["tokens"])[:2, :12])
    g_dense = generate(params, cfg, prompt, max_new=6)
    g_quant = generate(sq.params, cfg, prompt, lut=sq.lut, max_new=6)
    g_comp = generate(sc.params, cfg, prompt, lut=sc.lut, max_new=6)
    # lossless codec: compressed ≡ quantized
    np.testing.assert_array_equal(np.asarray(g_quant), np.asarray(g_comp))
    # int8 ≈ dense: generations agree on most tokens for a trained model
    agree = (np.asarray(g_dense) == np.asarray(g_quant)).mean()
    assert agree > 0.7, agree


def test_hlo_collective_stats_parses_synthetic():
    hlo = """
HloModule m

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %ar = f32[4]{0} all-reduce(%gte), replica_groups={}
  ROOT %t = (s32[], f32[4]) tuple(%iv, %ar)
}

%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%gte, %c), direction=LT
}

ENTRY %main (x: f32[4]) -> f32[4] {
  %x = f32[4]{0} parameter(0)
  %ag = f32[8]{0} all-gather(%x), dimensions={0}
  %w = (s32[], f32[4]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[4]{0} get-tuple-element(%w), index=1
}
"""
    st = hlo_stats.collective_stats(hlo)
    assert st.while_trips.get("body") == 12
    assert st.ops["all-reduce"] == 12          # trip-weighted
    assert st.bytes_by_kind["all-reduce"] == 12 * 16
    assert st.ops["all-gather"] == 1
    assert st.bytes_by_kind["all-gather"] == 32


def test_type_bytes_parser():
    assert hlo_stats._type_bytes("f32[2,3]") == 24
    assert hlo_stats._type_bytes("bf16[10]") == 20
    assert hlo_stats._type_bytes("(f32[2], u8[4])") == 12


def test_roofline_model_flops():
    from benchmarks.roofline import model_flops
    # train: 6·N·tokens ; decode: 2·N_active·batch
    cfg = get_config("qwen3-4b").full
    t = model_flops("qwen3-4b", "train_4k")
    assert t == 6.0 * cfg.n_active_params() * 4096 * 256
    d = model_flops("qwen3-4b", "decode_32k")
    assert d == 2.0 * cfg.n_active_params() * 128
    # MoE: active << total
    k = get_config("kimi-k2-1t-a32b").full
    assert k.n_active_params() < 0.1 * k.n_params()


def test_input_specs_cover_all_cells():
    from repro.launch.specs import SHAPES, input_specs, shape_applicable
    n_cells = 0
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch).full
        for shape in SHAPES:
            ok, why = shape_applicable(cfg, shape)
            cell = input_specs(arch, shape)
            assert cell["kind"] in ("train", "prefill", "decode")
            assert "batch" in cell
            n_cells += 1
    assert n_cells == 40  # 10 archs × 4 shapes


def test_serve_param_specs_shapes_match_builder():
    """Dry-run spec planning must agree with the real host-side builder."""
    from repro.launch.specs import serve_param_specs
    cfg = get_config("llama3.2-1b").smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    policy = CompressionPolicy(mode="quant", min_weight_size=1024)
    specs, lut = serve_param_specs(cfg, policy, jnp.float32)
    st = build_serve_params(params, policy)

    def shapes(tree):
        return sorted(tuple(x.shape) for x in jax.tree_util.tree_leaves(tree))

    assert shapes(specs) == shapes(st.params)
