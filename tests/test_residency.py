"""Tiered expert residency — parity, eviction, prefetch, integrity.

The acceptance contract of ``serve/residency.py``:

  * **Bitwise parity** — with the per-layer HBM cache capacity strictly
    below the expert count (including capacity 1), ``generate`` and the
    continuous-batching scheduler trace are bitwise-equal to the fully-
    resident path: the fetch/replay protocol guarantees every *routed*
    expert is resident before a step's outputs are committed, and absent
    experts only ever multiply zero gate rows (see apply_moe).
  * **No dense fallback** — a cache miss is a synchronous host→HBM fetch
    of compressed planes, never a dense materialization:
    ``MATERIALIZE_COUNTS['packed_stacked']`` stays 0 throughout.
  * **LRU eviction** — slots evict least-recently-used first, touches
    reorder the queue, and the generation-stamped slot table records
    install order.
  * **Routing-aware prefetch** — layer l's observed routing prefetches
    layer l+1 one layer ahead; first use of a prefetched slot counts
    ``prefetch_hit`` (nonzero under the deepseek routing trace).
  * **Integrity at fetch** — a corrupted backing-store plane raises
    ``IntegrityError`` naming (layer, expert, plane) at fetch time,
    before the bytes reach a cache slot.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import CompressionPolicy
from repro.core.integrity import IntegrityError
from repro.models import layers
from repro.models import lm as LM
from repro.serve import residency as res
from repro.serve.context import ServeContext
from repro.serve.engine import build_serve_params, generate
from repro.serve.residency import (RESIDENCY_COUNTS, ResidencyError,
                                   ResidencyManager)
from repro.serve.resilience import ResilientEngine
from repro.serve.scheduler import Request


@pytest.fixture(scope="module")
def served():
    """(cfg, ServeState, resident ctx) — deepseek smoke, dropless routing
    (capacity_factor=n_experts) so resident vs tiered parity is exact
    token-for-token, not merely distributional."""
    cfg = get_config("deepseek-v2-lite-16b").smoke
    cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    st = build_serve_params(
        params, CompressionPolicy(mode="compressed", min_weight_size=1024))
    return cfg, st, ServeContext.from_state(cfg, st)


def _prompt(cfg, n=8, seed=3):
    rng = np.random.RandomState(seed)
    return rng.randint(0, cfg.vocab_size, n).astype(np.int32)


def _tiered_ctx(ctx, mgr):
    return dataclasses.replace(ctx, residency=mgr)


# -- bitwise parity ----------------------------------------------------

def test_generate_parity_at_all_capacities(served):
    """generate under tiered residency is bitwise-equal to the fully-
    resident path at capacities {all, half, 1}, without ever
    materializing dense expert weights; constrained capacities actually
    exercise miss/replay, and the deepseek routing trace yields a
    nonzero prefetch-hit rate."""
    cfg, st, ctx = served
    prompt = _prompt(cfg)[None, :]
    ref = np.asarray(generate(st.params, cfg, prompt, ctx=ctx,
                              max_new=8, max_len=32))
    assert layers.MATERIALIZE_COUNTS["packed_stacked"] == 0
    for cap in (cfg.n_experts, cfg.n_experts // 2, 1):
        RESIDENCY_COUNTS.clear()
        mgr = ResidencyManager(st, cfg, capacity=cap)
        out = np.asarray(generate(st.params, cfg, prompt,
                                  ctx=_tiered_ctx(ctx, mgr),
                                  max_new=8, max_len=32))
        assert np.array_equal(out, ref), f"parity broke at capacity {cap}"
        assert layers.MATERIALIZE_COUNTS["packed_stacked"] == 0
        if cap < cfg.n_experts:
            assert RESIDENCY_COUNTS["miss"] > 0
            assert RESIDENCY_COUNTS["replay"] > 0
            assert RESIDENCY_COUNTS["prefetch_hit"] > 0
        assert RESIDENCY_COUNTS["sync_fetch"] >= RESIDENCY_COUNTS["miss"]
        assert RESIDENCY_COUNTS["bytes_fetched"] > 0


def test_generate_parity_sampled(served):
    """Temperature sampling splits the PRNG identically in the tiered
    host loop and the resident scan — same keys, same tokens."""
    cfg, st, ctx = served
    prompt = _prompt(cfg, seed=11)[None, :]
    key = jax.random.PRNGKey(42)
    ref = np.asarray(generate(st.params, cfg, prompt, ctx=ctx, max_new=6,
                              max_len=32, temperature=0.8, key=key))
    mgr = ResidencyManager(st, cfg, capacity=2)
    out = np.asarray(generate(st.params, cfg, prompt,
                              ctx=_tiered_ctx(ctx, mgr), max_new=6,
                              max_len=32, temperature=0.8, key=key))
    assert np.array_equal(out, ref)


def test_scheduler_trace_parity(served):
    """A mixed staggered trace through the continuous-batching scheduler
    under tiered residency finishes bitwise-equal to the resident
    scheduler serving the identical trace."""
    cfg, st, ctx = served
    rng = np.random.RandomState(17)
    prompts = [rng.randint(0, cfg.vocab_size,
                           int(rng.randint(4, 10))).astype(np.int32)
               for _ in range(4)]

    def run_trace(residency):
        eng = ResilientEngine(cfg, st, residency=residency).scheduler(
            n_slots=2, max_len=32, page_size=8)
        for i, p in enumerate(prompts):      # > n_slots: queue + join
            eng.submit(Request(tokens=p, max_new=6, rid=i))
            eng.step()
        done = {c.rid: c for c in eng.drain() + eng.completions}
        return [np.asarray(done[i].tokens) for i in range(len(prompts))]

    ref = run_trace(None)
    got = run_trace(ResidencyManager(st, cfg, capacity=3))
    for i, (r, g) in enumerate(zip(ref, got)):
        assert np.array_equal(r, g), f"scheduler trace diverged at rid {i}"
    assert layers.MATERIALIZE_COUNTS["packed_stacked"] == 0


# -- cache mechanics ---------------------------------------------------

def test_lru_eviction_order(served):
    """Vacant slots fill first; evictions then pick the least recently
    *used* expert — a touch (cache hit) reorders the LRU queue."""
    cfg, st, ctx = served
    mgr = ResidencyManager(st, cfg, capacity=2, prefetch=False)
    tail = [set()] * (mgr.n_layers - 1)
    mgr.step([{0}] + tail)
    mgr.step([{1}] + tail)
    assert set(mgr.resident(0)) == {0, 1}
    mgr.step([{0}] + tail)              # touch 0: LRU is now 1
    mgr.step([{2}] + tail)              # evicts 1, not 0
    assert set(mgr.resident(0)) == {0, 2}
    assert RESIDENCY_COUNTS["evict"] == 1
    # generation stamps record install order: 2 is the newest slot
    gens = {r.expert: r.gen for r in mgr.slot_table(0) if r.expert >= 0}
    assert gens[2] > gens[0]


def test_transient_overflow_trims_back(served):
    """A single step's working set may exceed capacity (capacity 1,
    top-k routing): the cache grows for the step and trims back to
    capacity at commit, evicting LRU-first."""
    cfg, st, ctx = served
    mgr = ResidencyManager(st, cfg, capacity=1, prefetch=False)
    tail = [set()] * (mgr.n_layers - 1)
    mgr.step([{3, 4, 5}] + tail)
    assert mgr.c_alloc == 1              # trimmed back after commit
    assert len(mgr.resident(0)) == 1
    assert RESIDENCY_COUNTS["evict"] == 2


def test_prefetch_hit_accounting(served):
    """Layer l's routing prefetches layer l+1 one layer ahead; the next
    step's first touch of those slots counts prefetch_hit, not hit."""
    cfg, st, ctx = served
    assert cfg.n_experts >= 4
    mgr = ResidencyManager(st, cfg, capacity=cfg.n_experts)
    tail = [set()] * (mgr.n_layers - 1)
    mgr.step([{1, 2}] + tail)            # predicts {1, 2} at layer 1
    before = RESIDENCY_COUNTS["prefetch_hit"]
    mgr.step([set(), {1, 2}] + tail[1:])
    assert RESIDENCY_COUNTS["prefetch_hit"] - before == 2
    assert RESIDENCY_COUNTS["prefetch_issued"] >= 2
    assert RESIDENCY_COUNTS["prefetch_installed"] >= 2
    # second touch of the same slots is a plain hit
    before_hit = RESIDENCY_COUNTS["hit"]
    mgr.step([set(), {1, 2}] + tail[1:])
    assert RESIDENCY_COUNTS["hit"] - before_hit == 2


# -- integrity ---------------------------------------------------------

def test_corrupt_backing_plane_caught_at_fetch(served):
    """Backing-store rot after construction is caught by the per-slice
    CRC at fetch time, naming (layer, expert, plane) — the corrupt bytes
    never reach a cache slot."""
    cfg, st, ctx = served
    mgr = ResidencyManager(st, cfg, capacity=2, prefetch=False)
    mgr._host["w_up"]["codes"][1, 5].reshape(-1).view(np.uint8)[0] ^= 0x40
    tail = [set()] * (mgr.n_layers - 1)
    mgr.step([{5}] + tail)               # layer 0, expert 5: clean
    with pytest.raises(IntegrityError) as ei:
        mgr.step([set(), {5}] + tail[1:])
    msg = str(ei.value)
    assert "w_up" in msg and "layer 1" in msg and "expert 5" in msg \
        and "codes" in msg
    assert 5 not in mgr.resident(1)


def test_manifest_verify_at_init(served):
    """Construction re-hashes the expert planes against the pack-time
    manifest — a pre-corrupted state refuses to build a backing store."""
    cfg, st, ctx = served
    from repro.testing import FaultInjector
    bad, leaf = FaultInjector(seed=5).flip_bit(st, "experts", "codes")
    with pytest.raises(IntegrityError):
        ResidencyManager(bad, cfg, capacity=2)
    # verify=False skips the (expensive) init gate; per-fetch CRCs are
    # recorded from the corrupt planes, so fetches then self-consist.
    ResidencyManager(bad, cfg, capacity=2, verify=False)


# -- wiring ------------------------------------------------------------

def test_residency_rejects_bad_wiring(served):
    cfg, st, ctx = served
    dense = get_config("llama3.2-1b").smoke
    dparams = LM.init_lm(jax.random.PRNGKey(0), dense, jnp.float32)
    dst = build_serve_params(
        dparams, CompressionPolicy(mode="compressed", min_weight_size=1024))
    with pytest.raises(ResidencyError):
        ResidencyManager(dst, dense, capacity=1)
    mgr = ResidencyManager(st, cfg, capacity=2)
    with pytest.raises(ResidencyError):
        res.make_tiered_serve_fns(
            dataclasses.replace(ctx, residency=mgr, mesh=object()))
    # serving a different params tree than the manager was built from
    prefill, _ = res.make_tiered_serve_fns(_tiered_ctx(ctx, mgr))
    with pytest.raises(ResidencyError):
        prefill({"blocks": {}}, st.lut, {"tokens": None, "embeds": None},
                None)


def test_health_and_reset_stats(served):
    """Engine.health() surfaces the residency snapshot alongside the
    lifecycle counters; reset_stats() clears RESIDENCY_COUNTS and the
    manager's counters too."""
    cfg, st, ctx = served
    mgr = ResidencyManager(st, cfg, capacity=2)
    reng = ResilientEngine(cfg, st, residency=mgr)
    eng = reng.scheduler(n_slots=2, max_len=32, page_size=8)
    eng.submit(Request(tokens=_prompt(cfg, 6), max_new=4, rid=0))
    eng.drain()
    h = eng.health()
    assert h["residency"]["miss"] > 0
    assert h["residency"]["bytes_fetched"] > 0
    assert reng.health()["residency"]["capacity"] == 2
    eng.reset_stats()
    assert sum(RESIDENCY_COUNTS.values()) == 0
    assert eng.health()["residency"]["miss"] == 0
    assert eng.health()["residency"]["stall_s"] == 0


def test_cache_bytes_capacity_and_budget(served):
    """cache_bytes sizes capacity in whole experts per layer; the
    core.policy.device_budget helper does the 4-8 GB edge math that
    launch/serve.py uses to default --expert-cache-mib."""
    cfg, st, ctx = served
    probe = ResidencyManager(st, cfg, capacity=1)
    per = probe.bytes_per_expert
    mgr = ResidencyManager(st, cfg,
                           cache_bytes=3 * probe.n_layers * per + 1)
    assert mgr.capacity == 3
    from repro.core.policy import device_budget
    b = device_budget(10 * probe.n_layers * per,
                      expert_bytes=probe.n_layers * probe.n_experts * per,
                      resident_bytes=3 * probe.n_layers * per)
    assert b.cache_experts_per_layer(probe.n_layers, per) == 7
    assert not b.fully_resident and b.fits
    assert "tiered" in b.summary()
    full = device_budget(1 << 40, expert_bytes=1 << 20)
    assert full.fully_resident


# -- runtime capacity (memory-pressure governor seam) -------------------

def test_runtime_capacity_shrink_and_regrow_bitwise(served):
    """Mid-stream set_capacity — down to 1, then back up — keeps the
    scheduler's outputs bitwise-equal to an undisturbed run: trims
    compact MRU-first, regrows add vacant slots, and the fetch/replay
    protocol re-fetches whatever the next step routes to."""
    cfg, st, ctx = served
    rng = np.random.RandomState(43)
    prompts = [rng.randint(0, cfg.vocab_size,
                           int(rng.randint(4, 10))).astype(np.int32)
               for _ in range(3)]

    def run_trace(capacities):
        """capacities: step index -> set_capacity target (applied at the
        step fence, mid-decode)."""
        mgr = ResidencyManager(st, cfg, capacity=3)
        eng = ResilientEngine(cfg, st, residency=mgr).scheduler(
            n_slots=2, max_len=32, page_size=8)
        for i, p in enumerate(prompts):
            eng.submit(Request(tokens=p, max_new=6, rid=i))
        while eng.health()["occupied"] or eng.health()["queued"]:
            if eng.steps in capacities:
                mgr.set_capacity(capacities[eng.steps])
            eng.step()
        eng.close()
        return {c.rid: np.asarray(c.tokens) for c in eng.completions}

    ref = run_trace({})
    got = run_trace({2: 1, 6: 3})        # shrink mid-decode, regrow later
    for i in range(len(prompts)):
        assert np.array_equal(ref[i], got[i]), \
            f"rid {i} diverged across runtime capacity shrink/regrow"
    assert layers.MATERIALIZE_COUNTS["packed_stacked"] == 0
    # bounds: clamps to [1, n_experts]; no-op change is free
    mgr = ResidencyManager(st, cfg, capacity=2, prefetch=False)
    mgr.set_capacity(0)
    assert mgr.capacity == 1 and mgr.overshoot_bytes > 0
    mgr.set_capacity(cfg.n_experts + 5)
    assert mgr.capacity == cfg.n_experts


def test_too_small_budget_warns_and_surfaces_overshoot(served):
    """satellite: a cache budget below one expert per layer used to be
    silently clamped to capacity 1 — it must warn, record the overshoot
    in the snapshot (-> health()['residency']), and show up in
    DeviceBudget.summary(expert_cache_used=...)."""
    cfg, st, ctx = served
    probe = ResidencyManager(st, cfg, capacity=1)
    floor = probe.n_layers * probe.bytes_per_expert
    with pytest.warns(RuntimeWarning, match="overshoot"):
        mgr = ResidencyManager(st, cfg, cache_bytes=floor // 2)
    assert mgr.capacity == 1
    assert mgr.overshoot_bytes == floor - floor // 2
    assert mgr.snapshot()["overshoot_bytes"] == mgr.overshoot_bytes
    # an adequate budget records zero overshoot
    assert probe.overshoot_bytes == 0
    from repro.core.policy import device_budget
    b = device_budget(floor // 2, expert_bytes=10 * floor)
    s = b.summary(expert_cache_used=floor)
    assert "OVERSHOOT" in s
    assert "OVERSHOOT" not in b.summary(expert_cache_used=0)


def test_close_stops_prefetch_worker_no_leaked_threads(served):
    """satellite: Engine/ResilientEngine teardown must stop the
    residency-prefetch worker thread; close is idempotent and the
    context-manager form covers the scheduler path."""
    import threading
    cfg, st, ctx = served

    def prefetch_threads():
        return {t for t in threading.enumerate()
                if t.name == "residency-prefetch" and t.is_alive()}

    before = prefetch_threads()          # workers leaked by earlier tests
    mgr = ResidencyManager(st, cfg, capacity=2)
    with ResilientEngine(cfg, st, residency=mgr) as reng:
        eng = reng.scheduler(n_slots=2, max_len=32, page_size=8)
        rng = np.random.RandomState(47)
        p = rng.randint(0, cfg.vocab_size, 6).astype(np.int32)
        eng.submit(Request(tokens=p, max_new=3, rid=0))
        eng.drain()
        assert len(prefetch_threads() - before) == 1     # worker ran
    assert prefetch_threads() - before == set()          # ...and was joined
    mgr.close()                                          # idempotent
    # a ResilientEngine that never built a scheduler still closes the
    # manager it owns
    mgr2 = ResidencyManager(st, cfg, capacity=2)
    mgr2._start_worker()
    reng2 = ResilientEngine(cfg, st, residency=mgr2)
    assert len(prefetch_threads() - before) == 1
    reng2.close()
    assert prefetch_threads() - before == set()
