"""Serving-engine tests — the paper's evaluation triple on a tiny model:
dense vs Quantized vs Compressed must agree per the paper's claims
(compressed ≡ quantized bit-exactly; both ≈ dense)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import CompressionPolicy
from repro.models import lm as LM
from repro.serve.engine import build_serve_params, make_serve_fns, generate


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3.2-1b").smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                              cfg.vocab_size)
    return cfg, params, toks


def _logits(cfg, params, lut, toks):
    out, _, _ = LM.forward(params, cfg, toks, lut=lut)
    return np.asarray(out, np.float32)


def test_compressed_equals_quantized_exactly(setup):
    """The dictionary codec is lossless over the quantized model — the
    paper's central exactness claim (§4 'match the original exactly')."""
    cfg, params, toks = setup
    # use a tiny min size so the smoke model's weights all qualify
    pol_q = CompressionPolicy(mode="quant", min_weight_size=1024)
    pol_c = CompressionPolicy(mode="compressed", min_weight_size=1024)
    sq = build_serve_params(params, pol_q)
    sc = build_serve_params(params, pol_c)
    lq = _logits(cfg, sq.params, sq.lut, toks)
    lc = _logits(cfg, sc.params, sc.lut, toks)
    np.testing.assert_array_equal(lq, lc)


def test_quantized_close_to_dense(setup):
    """8-bit quantization keeps logits close (accuracy-parity claim)."""
    cfg, params, toks = setup
    sq = build_serve_params(params, CompressionPolicy(mode="quant",
                                                      min_weight_size=1024))
    ld = _logits(cfg, params, None, toks)
    lq = _logits(cfg, sq.params, sq.lut, toks)
    # top-1 agreement on most positions (greedy decode parity)
    agree = (ld.argmax(-1) == lq.argmax(-1)).mean()
    assert agree > 0.9, agree


def test_compressed_smaller_than_quantized(setup):
    cfg, params, toks = setup
    sc = build_serve_params(params, CompressionPolicy(mode="compressed",
                                                      min_weight_size=1024))
    dense_bytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(params))
    total = sum(sc.stats.values())
    assert total < dense_bytes  # smaller than fp32 dense overall


def test_generate_greedy_deterministic(setup):
    cfg, params, toks = setup
    out1 = generate(params, cfg, toks, max_new=5)
    out2 = generate(params, cfg, toks, max_new=5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 15)


def test_generate_compressed_matches_quant(setup):
    cfg, params, toks = setup
    sq = build_serve_params(params, CompressionPolicy(mode="quant",
                                                      min_weight_size=1024))
    sc = build_serve_params(params, CompressionPolicy(mode="compressed",
                                                      min_weight_size=1024))
    gq = generate(sq.params, cfg, toks, lut=sq.lut, max_new=4)
    gc = generate(sc.params, cfg, toks, lut=sc.lut, max_new=4)
    np.testing.assert_array_equal(np.asarray(gq), np.asarray(gc))


def test_policy_excludes_norms_and_small(setup):
    cfg, params, toks = setup
    pol = CompressionPolicy(mode="compressed", min_weight_size=1024)
    st = build_serve_params(params, pol)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        st.params, is_leaf=lambda x: hasattr(x, "codes"))
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if "norm" in name:
            assert not hasattr(leaf, "codes"), name


def test_prefill_decode_consistency_compressed(setup):
    """Cache built by compressed prefill serves exact decode steps."""
    cfg, params, toks = setup
    sc = build_serve_params(params, CompressionPolicy(mode="compressed",
                                                      min_weight_size=1024))
    prefill, decode = make_serve_fns(cfg)
    caches = LM.init_caches(cfg, 2, 12, dtype=jnp.float32)
    last, caches = prefill(sc.params, sc.lut, {"tokens": toks}, caches)
    nxt = jnp.argmax(last, axis=-1)[:, None].astype(toks.dtype)
    logits2, _ = decode(sc.params, sc.lut, nxt, caches, 10)
    # reference: dense forward over the 11-token sequence
    seq = jnp.concatenate([toks, nxt], axis=1)
    sq = build_serve_params(params, CompressionPolicy(mode="quant",
                                                      min_weight_size=1024))
    ref_logits, _, _ = LM.forward(sq.params, cfg, seq, lut=sq.lut)
    np.testing.assert_allclose(np.asarray(logits2),
                               np.asarray(ref_logits[:, -1]),
                               rtol=2e-2, atol=2e-3)


def test_generate_decode_loop_single_trace(setup):
    """The decode phase must run under one lax.scan trace — the per-token
    Python loop used to retrace (and host-sync) every step."""
    from repro.serve import engine
    cfg, params, toks = setup
    engine.TRACE_COUNTS.clear()
    out = generate(params, cfg, toks, max_new=12)
    assert out.shape == (2, 22)
    # decode_step's Python body runs only while tracing; one scanned trace
    # executes it a small constant number of times (abstract eval + lower),
    # never once per generated token.
    assert 0 < engine.TRACE_COUNTS["decode_step"] < 5, \
        dict(engine.TRACE_COUNTS)
    assert engine.TRACE_COUNTS["decode_loop"] == 1, \
        dict(engine.TRACE_COUNTS)
    # same shapes again -> fully cached, no retrace at all
    engine.TRACE_COUNTS.clear()
    generate(params, cfg, toks, max_new=12)
    assert engine.TRACE_COUNTS["decode_loop"] == 0, \
        dict(engine.TRACE_COUNTS)
    assert engine.TRACE_COUNTS["decode_step"] == 0


def test_make_serve_fns_jitted_and_cached(setup):
    """Default closures are jit-wrapped and cached per config, so repeated
    callers share one executable; jit=False returns raw closures."""
    cfg, params, toks = setup
    p1, d1 = make_serve_fns(cfg)
    p2, d2 = make_serve_fns(cfg)
    assert p1 is p2 and d1 is d2
    praw, draw = make_serve_fns(cfg, jit=False)
    assert praw is not p1
    from repro.serve import engine
    engine.TRACE_COUNTS.clear()
    from repro.models import lm as LM
    caches = LM.init_caches(cfg, 2, 14, dtype=jnp.float32)
    logits1, c1 = p1(params, None, {"tokens": toks}, caches)
    logits2, _ = p1(params, None, {"tokens": toks}, caches)
    assert engine.TRACE_COUNTS["prefill"] <= 1  # 2nd call: no retrace
    np.testing.assert_array_equal(np.asarray(logits1), np.asarray(logits2))


def test_generate_sampled_scan_matches_shapes(setup):
    cfg, params, toks = setup
    out = generate(params, cfg, toks, max_new=6, temperature=0.8,
                   key=jax.random.PRNGKey(3))
    assert out.shape == (2, 16)
    # deterministic under the same key
    out2 = generate(params, cfg, toks, max_new=6, temperature=0.8,
                    key=jax.random.PRNGKey(3))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_serve_stats_report_compression(setup):
    cfg, params, toks = setup
    sc = build_serve_params(params, CompressionPolicy(mode="compressed",
                                                      min_weight_size=1024))
    assert sc.stats["compressed"] > 0
    # random-init weights are near-uniform in int8 → table may be empty
    # (all-escape streams stay lossless); structured weights must populate it
    structured = jax.tree_util.tree_map(
        lambda x: jnp.round(x * 2) / 2 if x.ndim >= 2 else x, params)
    st2 = build_serve_params(structured,
                             CompressionPolicy(mode="compressed",
                                               min_weight_size=1024))
    assert st2.table is not None and len(st2.table) > 0
    # dictionary hits make the structured model smaller than the random one
    assert st2.stats["compressed"] < sc.stats["compressed"]
