"""Memory-pressure governor — reclaim/regrow ladder under budget traces.

The acceptance contract (ROADMAP §memory pressure): under any pressure
trace — step, spike, ramp, oscillate — the engine

  * keeps its *accounted* footprint (usable KV pages + expert-cache
    capacity) within the instantaneous budget, reclaiming at the next
    step fence;
  * ends every affected request as an accounted-for ``Completion``
    (``finished`` ∈ {eos, max_new, shed, deadline, refused, pressure});
  * serves survivors **bitwise-equal** to an unpressured run (pressure
    moves where KV lives and when requests run, never what they
    compute);
  * never thrashes: oscillation inside a hysteresis band produces zero
    plan changes, so the retrace count is bounded by sustained band
    crossings — not by trace length;
  * leaks nothing: teardown (``Engine.close``) stops the residency
    prefetch worker.
"""
import dataclasses
import threading

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import CompressionPolicy
from repro.core.policy import device_budget
from repro.models import lm as LM
from repro.serve import engine as engine_mod
from repro.serve.context import ServeContext
from repro.serve.engine import build_serve_params, generate
from repro.serve.governor import MemoryGovernor
from repro.serve.kv_cache import PagedKVPool
from repro.serve.resilience import FALLBACK_COUNTS
from repro.serve.scheduler import Engine, Request
from repro.testing import (FaultInjector, PRESSURE_KINDS, pressure_trace)

ACCOUNTED = {"eos", "max_new", "shed", "deadline", "refused", "pressure"}


@pytest.fixture(scope="module")
def served():
    cfg = get_config("llama3.2-1b").smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    st = build_serve_params(
        params, CompressionPolicy(mode="compressed", min_weight_size=1024))
    return cfg, st, ServeContext.from_state(cfg, st)


def _prompts(cfg, n, seed=100):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size,
                        int(rng.randint(4, 10))).astype(np.int32)
            for _ in range(n)]


def _ref(st, cfg, ctx, prompt, max_new, max_len):
    return np.asarray(generate(st.params, cfg, prompt[None, :], ctx=ctx,
                               max_new=max_new, max_len=max_len))[0]


def _kv_budget(cfg, n_slots=2, max_len=16, page_size=8):
    """(DeviceBudget sized to exactly the boot KV pool, page_nbytes) —
    resident/act/expert reserves zero, so the governor's plan math is
    transparent: budget k*page_nbytes ⇒ k usable pages."""
    pool = PagedKVPool(cfg, n_slots, max_len, page_size=page_size)
    pn = pool.page_nbytes()
    boot = pool.n_pages * pn
    return device_budget(boot, expert_bytes=0, kv_bytes=boot), pn


# -- the pressure-trace generator ---------------------------------------

def test_pressure_trace_shapes_and_seeding():
    boot, low = 1000, 400
    for kind in PRESSURE_KINDS:
        tr = pressure_trace(kind, boot_bytes=boot, low_bytes=low,
                            n_steps=32, seed=3)
        assert len(tr) == 32
        assert min(tr) >= low and max(tr) <= boot
        assert tr == pressure_trace(kind, boot_bytes=boot, low_bytes=low,
                                    n_steps=32, seed=3)   # reproducible
    step = pressure_trace("step", boot_bytes=boot, low_bytes=low,
                          n_steps=32, seed=3)
    assert step[0] == boot and step[-1] == low
    spike = pressure_trace("spike", boot_bytes=boot, low_bytes=low,
                           n_steps=32, seed=3)
    assert spike[0] == boot and spike[-1] == boot and low in spike
    ramp = pressure_trace("ramp", boot_bytes=boot, low_bytes=low,
                          n_steps=32, seed=3)
    assert ramp[0] == boot and min(ramp) == low and ramp[-1] == boot
    osc = pressure_trace("oscillate", boot_bytes=boot, low_bytes=low,
                         n_steps=32, period=4, seed=3)
    assert set(osc) == {boot, low}
    with pytest.raises(ValueError, match="kind"):
        pressure_trace("cliff", boot_bytes=boot, low_bytes=low, n_steps=8)


# -- reclaim ladder ------------------------------------------------------

def test_reclaim_preempts_and_survivors_stay_bitwise(served):
    """Budget halves mid-decode with both slots live and zero free pages:
    rung 2 must preempt the victim (no strictly-lower-priority check —
    the pool itself shrinks), retire its pages, and the victim resumes
    bitwise-equal once the other tenant finishes."""
    cfg, st, ctx = served
    budget, pn = _kv_budget(cfg)
    gov = MemoryGovernor(budget)
    eng = Engine(ctx, st.params, n_slots=2, max_len=16, governor=gov)
    p0, p1 = [p[:6] for p in _prompts(cfg, 2, seed=51)]
    eng.submit(Request(tokens=p0, max_new=8, rid=0))
    eng.submit(Request(tokens=p1, max_new=8, rid=1))
    eng.step()                      # both in flight; all pages owned
    gov.set_budget(2 * pn)          # room for exactly one slot
    eng.step()                      # fence: reclaim walks the ladder
    assert eng.pool.n_pages_usable == 2
    assert eng.pool.device_bytes() <= 2 * pn     # tail physically gone
    assert FALLBACK_COUNTS["pressure_kv_retire"] >= 1
    assert FALLBACK_COUNTS["pressure_preempt"] == 1
    assert eng.health()["pressure"]["plan"]["pages"] == 2
    eng.drain()
    by_rid = {c.rid: c for c in eng.completions}
    assert by_rid[0].finished == "max_new" and by_rid[1].finished == "max_new"
    assert {by_rid[0].resumed, by_rid[1].resumed} == {0, 1}   # one victim
    for rid, p in ((0, p0), (1, p1)):
        np.testing.assert_array_equal(
            by_rid[rid].tokens, _ref(st, cfg, ctx, p, 8, eng.pool.max_len),
            err_msg=f"request {rid} diverged under pressure")


def test_reclaim_tightens_admission(served):
    """With the pool shrunk to one slot's worth the governor caps
    max_queue at the backing slot count; the overflow sheds through the
    existing bounded-queue path."""
    cfg, st, ctx = served
    budget, pn = _kv_budget(cfg)
    gov = MemoryGovernor(budget)
    eng = Engine(ctx, st.params, n_slots=2, max_len=16, governor=gov)
    gov.set_budget(2 * pn)
    eng.step()
    assert eng.max_queue == 1
    assert FALLBACK_COUNTS["pressure_tighten"] == 1
    p = _prompts(cfg, 1, seed=53)[0][:6]
    eng.submit(Request(tokens=p, max_new=2, rid=0))
    eng.step()                                        # rid 0 admitted
    eng.submit(Request(tokens=p, max_new=2, rid=1))   # queued (1/1)
    eng.submit(Request(tokens=p, max_new=2, rid=2))   # overflow: sheds
    eng.drain()
    by_rid = {c.rid: c for c in eng.completions}
    assert by_rid[2].finished == "shed"
    assert all(by_rid[i].finished == "max_new" for i in (0, 1))


def test_refuse_below_floor_then_recover(served):
    """Below min_viable the governor clamps at the floors and refuses new
    submissions as finished='pressure'; queued/in-flight work still
    drains.  When the budget recovers (sustained past the hysteresis
    cooldown) the regrow ladder restores the boot plan and admission."""
    cfg, st, ctx = served
    budget, pn = _kv_budget(cfg)
    gov = MemoryGovernor(budget, cooldown_steps=3)
    eng = Engine(ctx, st.params, n_slots=2, max_len=16, governor=gov)
    p = _prompts(cfg, 1, seed=55)[0][:6]
    eng.submit(Request(tokens=p, max_new=3, rid=0))
    gov.set_budget(pn)               # below the one-slot KV floor
    eng.step()
    assert gov.refusing
    assert eng.pool.n_pages_usable == eng.pool.pages_per_slot  # floor holds
    rid = eng.submit(Request(tokens=p, max_new=3, rid=9))
    refused = [c for c in eng.completions if c.rid == rid]
    assert len(refused) == 1 and refused[0].finished == "pressure"
    assert refused[0].n_generated == 0
    assert FALLBACK_COUNTS["pressure_refused"] == 1
    eng.drain()                      # the admitted request still finishes
    assert {c.rid: c.finished for c in eng.completions}[0] == "max_new"
    # recovery: sustained boot budget regrows pages and lifts the refusal
    gov.set_budget(budget.budget_bytes)
    for _ in range(gov.cooldown_steps + 1):
        eng.step()
    assert not gov.refusing
    assert eng.pool.n_pages_usable == eng.pool.n_pages
    assert eng.max_queue is None
    assert FALLBACK_COUNTS["pressure_regrow"] >= 1
    eng.submit(Request(tokens=p, max_new=3, rid=10))
    [c] = eng.drain()
    assert c.finished == "max_new"
    np.testing.assert_array_equal(
        c.tokens, _ref(st, cfg, ctx, p, 3, eng.pool.max_len))


# -- hysteresis / no-thrash ----------------------------------------------

def test_oscillation_never_thrashes_or_retraces_per_step(served):
    """A fast square-wave trace (period 2 < cooldown 4): after the first
    reclaim the hysteresis band swallows every flip — plan changes and
    generate_step traces are bounded by band crossings, not steps."""
    cfg, st, ctx = served
    cfgf = dataclasses.replace(cfg, name=cfg.name + "-gov-osc")
    ctxf = ctx.with_cfg(cfgf)
    budget, pn = _kv_budget(cfg)
    gov = MemoryGovernor(budget, cooldown_steps=4)
    eng = Engine(ctxf, st.params, n_slots=2, max_len=16, governor=gov)
    prompts = [p[:6] for p in _prompts(cfg, 3, seed=57)]
    for i, p in enumerate(prompts):
        eng.submit(Request(tokens=p, max_new=6, rid=i))
    trace = pressure_trace("oscillate", boot_bytes=budget.budget_bytes,
                           low_bytes=2 * pn, n_steps=64, period=2, seed=9)
    engine_mod.TRACE_COUNTS.clear()
    with FaultInjector().memory_pressure(trace) as probe:
        eng.drain()
        steps_under_trace = probe.executions
    assert steps_under_trace >= 8            # the trace really drove steps
    # one reclaim when the first low lands; flips inside the band do
    # nothing; at most one regrow if the tail held high long enough
    assert gov.plan_changes <= 2, gov.snapshot()
    assert engine_mod.TRACE_COUNTS["generate_step"] <= 1 + gov.plan_changes
    assert all(c.finished in ACCOUNTED for c in eng.completions)
    by_rid = {c.rid: c for c in eng.completions}
    for i, p in enumerate(prompts):
        if by_rid[i].finished == "max_new":
            np.testing.assert_array_equal(
                by_rid[i].tokens,
                _ref(st, cfg, ctx, p, 6, eng.pool.max_len),
                err_msg=f"survivor {i} diverged under oscillation")


def test_ramp_reclaims_then_regrows_to_boot(served):
    """A ramp down and back up: reclaim tracks the descent immediately,
    regrow climbs behind hysteresis (far fewer plan changes than steps),
    and the engine ends back at the boot envelope."""
    cfg, st, ctx = served
    budget, pn = _kv_budget(cfg)
    gov = MemoryGovernor(budget, cooldown_steps=2)
    eng = Engine(ctx, st.params, n_slots=2, max_len=16, governor=gov)
    trace = pressure_trace("ramp", boot_bytes=budget.budget_bytes,
                           low_bytes=2 * pn, n_steps=30, seed=13)
    with FaultInjector().memory_pressure(trace):
        for _ in range(len(trace) + 10):     # hold_last keeps boot at end
            eng.step()
    assert eng.pool.n_pages_usable == eng.pool.n_pages   # fully regrown
    assert not gov.refusing
    assert 0 < gov.plan_changes < len(trace)
    assert FALLBACK_COUNTS["pressure_regrow"] >= 1
    lat = gov.snapshot()["rung_latency_s"]
    assert "retire_kv" in lat and lat["retire_kv"] >= 0.0


# -- accounting under every trace kind -----------------------------------

@pytest.mark.parametrize("kind", PRESSURE_KINDS)
def test_every_trace_kind_drains_fully_accounted(served, kind):
    """The blanket invariant: any trace kind, staggered arrivals — the
    engine drains, and every request ends as an accounted Completion."""
    cfg, st, ctx = served
    budget, pn = _kv_budget(cfg)
    gov = MemoryGovernor(budget, cooldown_steps=3)
    eng = Engine(ctx, st.params, n_slots=2, max_len=16, governor=gov)
    prompts = [p[:6] for p in _prompts(cfg, 4, seed=59)]
    trace = pressure_trace(kind, boot_bytes=budget.budget_bytes,
                           low_bytes=2 * pn, n_steps=48)
    with FaultInjector().memory_pressure(trace):
        submitted = 0
        while submitted < 4 or eng.health()["occupied"] \
                or eng.health()["queued"]:
            if submitted < 4 and eng.steps >= 2 * submitted:
                eng.submit(Request(tokens=prompts[submitted], max_new=5,
                                   rid=submitted))
                submitted += 1
            eng.step()
    reasons = {c.rid: c.finished for c in eng.completions}
    assert set(reasons) == {0, 1, 2, 3}, reasons
    assert all(r in ACCOUNTED for r in reasons.values()), reasons
    # the accounted KV footprint respects the applied plan
    assert eng.pool.n_pages_usable == gov.applied_plan.pages
    by_rid = {c.rid: c for c in eng.completions}
    for i, p in enumerate(prompts):
        if by_rid[i].finished == "max_new":
            np.testing.assert_array_equal(
                by_rid[i].tokens, _ref(st, cfg, ctx, p, 5, eng.pool.max_len),
                err_msg=f"survivor {i} diverged under {kind} trace")


# -- injection seam ------------------------------------------------------

def test_memory_pressure_seam_drives_governor(served):
    cfg, st, ctx = served
    budget, pn = _kv_budget(cfg)
    gov = MemoryGovernor(budget)
    eng = Engine(ctx, st.params, n_slots=2, max_len=16, governor=gov)
    with FaultInjector().memory_pressure([3 * pn, 2 * pn]) as probe:
        eng.step()
        assert gov.target_bytes == 3 * pn
        eng.step()
        assert gov.target_bytes == 2 * pn
        eng.step()                           # hold_last repeats the tail
        assert gov.target_bytes == 2 * pn
    assert probe.executions == 3
    eng.step()                               # seam restored: no signal
    assert gov.target_bytes == 2 * pn
    snap = eng.health()["pressure"]
    assert snap["applied_bytes"] == 2 * pn
    assert snap["kv_pages_usable"] == 2


# -- tiered residency: experts absorb the deficit first ------------------

def test_governor_trims_expert_cache_before_kv():
    """MoE under tiered residency: a deficit smaller than the expert
    cache trims capacity (rung 1) and pauses prefetch, leaving the KV
    pool untouched; recovery regrows capacity and resumes prefetch.
    Outputs stay bitwise-equal throughout (the residency parity
    contract at any capacity ≥ 1)."""
    from repro.serve.residency import ResidencyManager
    cfg = get_config("deepseek-v2-lite-16b").smoke
    cfg = dataclasses.replace(cfg, name=cfg.name + "-gov-tier",
                              capacity_factor=float(cfg.n_experts))
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    st = build_serve_params(
        params, CompressionPolicy(mode="compressed", min_weight_size=1024))
    ctx = ServeContext.from_state(cfg, st)
    prompts = [p[:6] for p in _prompts(cfg, 2, seed=61)]
    refs = [_ref(st, cfg, ctx, p, 4, 16) for p in prompts]

    mgr = ResidencyManager(st, cfg, capacity=3)
    unit = mgr.n_layers * mgr.bytes_per_expert
    pool = PagedKVPool(cfg, 2, 16, page_size=8)
    kv_boot = pool.n_pages * pool.page_nbytes()
    budget = device_budget(kv_boot + 3 * unit, expert_bytes=unit * 3,
                           kv_bytes=kv_boot)
    gov = MemoryGovernor(budget, cooldown_steps=2)
    eng = Engine(dataclasses.replace(ctx, residency=mgr), st.params,
                 n_slots=2, max_len=16, governor=gov)
    eng.submit(Request(tokens=prompts[0], max_new=4, rid=0))
    eng.step()
    gov.set_budget(kv_boot + unit)       # deficit = 2 experts/layer
    eng.step()
    assert mgr.capacity == 1
    assert not mgr.prefetch_enabled      # paused under pressure
    assert eng.pool.n_pages_usable == eng.pool.n_pages   # KV untouched
    assert FALLBACK_COUNTS["pressure_trim"] == 1
    assert FALLBACK_COUNTS["pressure_kv_retire"] == 0
    eng.drain()
    gov.set_budget(budget.budget_bytes)  # sustained recovery
    for _ in range(gov.cooldown_steps + 1):
        eng.step()
    assert mgr.capacity == 3
    assert mgr.prefetch_enabled          # resumed at full recovery
    eng.submit(Request(tokens=prompts[1], max_new=4, rid=1))
    eng.drain()
    by_rid = {c.rid: c for c in eng.completions}
    for i in range(2):
        np.testing.assert_array_equal(
            by_rid[i].tokens, refs[i],
            err_msg=f"request {i} diverged across trim/regrow")
    eng.close()
    assert not any(t.name == "residency-prefetch" and t.is_alive()
                   for t in threading.enumerate())


# -- teardown ------------------------------------------------------------

def test_engine_close_is_idempotent_and_context_managed(served):
    cfg, st, ctx = served
    with Engine(ctx, st.params, n_slots=1, max_len=16) as eng:
        p = _prompts(cfg, 1, seed=63)[0][:6]
        eng.submit(Request(tokens=p, max_new=2))
        eng.drain()
    eng.close()                          # second close: no-op
