"""Partition-rule and mesh tests.

Rule-table tests run against fabricated meshes via Mesh(np.array(...))
abstract construction where possible; the full 512-device behaviour is
exercised in a subprocess (XLA device count is locked at first init, so
the main test process stays single-device).
"""
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_host_mesh
from repro.sharding import partition as PT


def test_host_mesh_rules_replicate():
    """On a (1,1) mesh every rule is divisibility-guarded to no-op."""
    mesh = make_host_mesh()
    params = {"blocks": {"attn": {"wq": jnp.zeros((4, 64, 32))},
                         "mlp": {"w_down": jnp.zeros((4, 32, 64))}}}
    specs = PT.make_param_specs(params, mesh)
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(s, P) for s in leaves)


def test_constrain_noop_without_mesh():
    x = jnp.zeros((8, 4))
    y = PT.constrain(x, ("pod", "data"), "model")
    assert y.shape == x.shape


def test_constrain_divisibility_guard():
    mesh = make_host_mesh()
    with PT.active_mesh(mesh):
        # (7,) doesn't divide anything — must silently no-op, not raise
        y = PT.constrain(jnp.zeros((7, 3)), "data", "model")
        assert y.shape == (7, 3)


_SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.sharding import partition as PT

mesh = jax.make_mesh((2, 8), ("data", "model"))

# --- dense rules ---
params = {
    "embed": jax.ShapeDtypeStruct((1024, 64), jnp.float32),
    "blocks": {
        "attn": {"wq": jax.ShapeDtypeStruct((4, 64, 64), jnp.float32),
                 "wo": jax.ShapeDtypeStruct((4, 64, 64), jnp.float32),
                 "q_norm": jax.ShapeDtypeStruct((4, 16), jnp.float32)},
        "mlp": {"w_gate": jax.ShapeDtypeStruct((4, 128, 64), jnp.float32)},
        "moe": {"experts": {"w_gate":
                jax.ShapeDtypeStruct((4, 16, 32, 64), jnp.float32)}},
    },
}
specs = PT.make_param_specs(params, mesh, PT.ShardingConfig(mode="train"))
assert specs["blocks"]["attn"]["wq"] == P(None, "model", "data"), specs
assert specs["blocks"]["attn"]["wo"] == P(None, "data", "model")
assert specs["blocks"]["attn"]["q_norm"] == P(None, None)
assert specs["blocks"]["mlp"]["w_gate"] == P(None, "model", "data")
assert specs["blocks"]["moe"]["experts"]["w_gate"] == P(None, "model", None, "data")
assert specs["embed"] == P("model", "data")

# --- compressed planes follow the dense out-dim ---
# (1024x512 weight -> 128 codec blocks, divisible by the 8-way model axis)
from repro.core.compressed import planned_packed_specs
pl = planned_packed_specs((1024, 512), stacked=(4,))
params_c = {"blocks": {"mlp": {"w_gate": pl}}}
specs_c = PT.make_param_specs(params_c, mesh,
                              PT.ShardingConfig(mode="serve",
                                                fsdp_weights=False))
assert specs_c["blocks"]["mlp"]["w_gate"].codes == P(None, "model", None), \
    specs_c["blocks"]["mlp"]["w_gate"].codes
# fsdp stacks data onto the plane block axis
specs_f = PT.make_param_specs(params_c, mesh,
                              PT.ShardingConfig(mode="serve",
                                                fsdp_weights=True))
assert specs_f["blocks"]["mlp"]["w_gate"].codes == P(None, ("data", "model"), None)

# --- caches: heads shard when divisible, else time ---
caches = {"blocks": {"k": jax.ShapeDtypeStruct((4, 8, 64, 8, 16), jnp.float32),
                     "v": jax.ShapeDtypeStruct((4, 8, 64, 4, 16), jnp.float32)}}
cs = PT.make_cache_specs(caches, mesh)
assert cs["blocks"]["k"] == P(None, ("data",), None, "model", None), cs
assert cs["blocks"]["v"] == P(None, ("data",), "model", None, None), cs

# --- data specs ---
ds = PT.make_data_specs({"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)},
                        mesh)
assert ds["tokens"] == P(("data",), None)

# --- constrain inside jit with the active mesh ---
with mesh, PT.active_mesh(mesh):
    def f(x):
        return PT.constrain(x, "data", "model") * 2
    y = jax.jit(f)(jnp.zeros((4, 16)))
    ns = y.sharding
    assert ns.spec == P("data", "model"), ns

print("SUBPROC_OK")
"""


@pytest.mark.slow
def test_partition_rules_16dev_subprocess():
    r = subprocess.run([sys.executable, "-c", _SUBPROC_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "SUBPROC_OK" in r.stdout, r.stdout + r.stderr


def test_train_state_specs_structure():
    from repro.train.optimizer import AdamWConfig, adamw_init, QMoment
    mesh = make_host_mesh()
    params = {"w": jnp.zeros((8, 512))}
    state = {"params": params,
             "opt": adamw_init(params, AdamWConfig(quantized_state=True,
                                                   qblock=128))}
    specs = PT.make_train_state_specs(state, mesh)
    qm = specs["opt"]["mu"]["w"]["m"]
    assert isinstance(qm, QMoment)
    assert isinstance(qm.q, P) and isinstance(qm.scale, P)


def test_shard_aligned_mesh_constants():
    from repro.launch.mesh import AXIS_DATA, AXIS_MODEL, AXIS_POD
    assert (AXIS_POD, AXIS_DATA, AXIS_MODEL) == ("pod", "data", "model")


_MOE_LOCAL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import layers as L
from repro.sharding import partition as PT

cfg = dataclasses.replace(get_config("deepseek-v2-lite-16b").smoke,
                          capacity_factor=64.0)   # dropless => exact match
p = L.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model)) * 0.5
y_g, aux_g = L.apply_moe(p, x, cfg)

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg_l = dataclasses.replace(cfg, moe_local_dispatch=True)
with mesh, PT.active_mesh(mesh):
    y_l, aux_l = jax.jit(lambda p_, x_: L.apply_moe(p_, x_, cfg_l))(p, x)
assert float(jnp.abs(y_g - y_l).max()) < 1e-5, "local dispatch != global"
print("MOE_LOCAL_OK")
"""


@pytest.mark.slow
def test_moe_local_dispatch_matches_global_subprocess():
    """shard_map local-routing MoE (§Perf DP3) ≡ global dispatch when
    dropless (capacity semantics are per-shard otherwise)."""
    r = subprocess.run([sys.executable, "-c", _MOE_LOCAL_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "MOE_LOCAL_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-1500:]
