"""Training-substrate tests: loss descent, chunked CE, accumulation,
int8 optimizer state, gradient compression."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm as LM
from repro.train.data import DataConfig, DataPipeline
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   QMoment, lr_schedule, moment_block)
from repro.train.steps import (TrainConfig, make_train_step,
                               init_train_state, cross_entropy,
                               chunked_cross_entropy, compress_grads_int8)


def _tiny():
    cfg = get_config("llama3.2-1b").smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    return cfg, params


def test_loss_decreases_on_learnable_data():
    cfg, params = _tiny()
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size, batch=16,
                                   seq_len=32, seed=3))
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-2, warmup_steps=10,
                                             total_steps=2000))
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    losses = []
    for i in range(80):
        state, m = step(state, data.batch_at(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 1.0, losses


def test_chunked_ce_matches_full(rng):
    b, t, d, v = 2, 16, 8, 32
    hidden = jnp.asarray(rng.normal(size=(b, t, d)).astype(np.float32))
    head = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, v, size=(b, t)))
    full = cross_entropy(jnp.einsum("btd,vd->btv", hidden, head), labels,
                         z_loss=1e-4)
    for chunk in (4, 8, 16, 5):
        ch = chunked_cross_entropy(hidden, head, labels, chunk=chunk,
                                   z_loss=1e-4)
        np.testing.assert_allclose(float(ch), float(full), rtol=1e-5)


def test_chunked_ce_gradients_match(rng):
    b, t, d, v = 2, 8, 4, 16
    hidden = jnp.asarray(rng.normal(size=(b, t, d)).astype(np.float32))
    head = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, v, size=(b, t)))
    g_full = jax.grad(lambda h: cross_entropy(
        jnp.einsum("btd,vd->btv", h, head), labels))(hidden)
    g_chunk = jax.grad(lambda h: chunked_cross_entropy(
        h, head, labels, chunk=4))(hidden)
    np.testing.assert_allclose(np.asarray(g_chunk), np.asarray(g_full),
                               rtol=1e-4, atol=1e-6)


def test_accumulation_matches_single_batch():
    """accum_steps=k over a batch == one step over the same batch (mean)."""
    cfg, params = _tiny()
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size, batch=8,
                                   seq_len=8, seed=1))
    batch = data.batch_at(0)
    outs = {}
    for accum in (1, 4):
        tcfg = TrainConfig(accum_steps=accum)
        state = init_train_state(params, tcfg)
        step = jax.jit(make_train_step(cfg, tcfg))
        new_state, m = step(state, batch)
        outs[accum] = (float(m["loss"]),
                       np.asarray(jax.tree_util.tree_leaves(
                           new_state["params"])[0]))
    assert outs[1][0] == pytest.approx(outs[4][0], rel=1e-4)
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=1e-3, atol=1e-5)


# ---------------------------------------------------------------------------
# Optimizer.
# ---------------------------------------------------------------------------

def test_adamw_quantized_state_tracks_fp32(rng):
    """int8 moments: updates stay close to exact AdamW over many steps."""
    w = jnp.asarray(rng.normal(size=(16, 256)).astype(np.float32))
    cfg_q = AdamWConfig(lr=1e-2, quantized_state=True, qblock=64,
                        warmup_steps=0)
    cfg_f = AdamWConfig(lr=1e-2, quantized_state=False, warmup_steps=0)
    pq, pf = {"w": w}, {"w": w}
    sq, sf = adamw_init(pq, cfg_q), adamw_init(pf, cfg_f)
    assert isinstance(sq["mu"]["w"]["m"], QMoment)
    for i in range(20):
        g = {"w": jnp.asarray(rng.normal(size=w.shape).astype(np.float32))}
        pq, sq, _ = adamw_update(pq, g, sq, cfg_q)
        pf, sf, _ = adamw_update(pf, g, sf, cfg_f)
    rel = float(jnp.linalg.norm(pq["w"] - pf["w"]) /
                jnp.linalg.norm(pf["w"] - w))
    assert rel < 0.15, rel  # drift bounded (8-bit Adam regime)


def test_moment_block_divides():
    assert moment_block(16384, 256) == 256
    assert moment_block(448, 256) == 224 or 448 % moment_block(448, 256) == 0
    assert moment_block(7, 256) == 7


def test_qmoment_shapes_mirror_param(rng):
    p = {"w": jnp.zeros((4, 6, 512), jnp.float32)}
    cfg = AdamWConfig(quantized_state=True, qblock=128)
    st = adamw_init(p, cfg)
    qm = st["mu"]["w"]["m"]
    assert qm.q.shape == (4, 6, 4, 128)
    assert qm.scale.shape == (4, 6, 4, 1)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(lr_schedule(0, cfg)) == pytest.approx(0.0)
    assert float(lr_schedule(10, cfg)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr_schedule(100, cfg)) == pytest.approx(1e-4, rel=1e-2)


def test_grad_clip_applies(rng):
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)  # lr 0: only metrics matter
    p = {"w": jnp.zeros((8, 8), jnp.float32)}
    st = adamw_init(p, cfg)
    g = {"w": jnp.full((8, 8), 100.0)}
    _, _, m = adamw_update(p, g, st, cfg)
    assert float(m["grad_norm"]) == pytest.approx(800.0)


# ---------------------------------------------------------------------------
# Gradient compression (int8 + error feedback).
# ---------------------------------------------------------------------------

def test_grad_compression_error_feedback_unbiased(rng):
    """Summed over steps, EF compensates: Σ dq ≈ Σ g."""
    g_sum = np.zeros((32, 32), np.float32)
    dq_sum = np.zeros((32, 32), np.float32)
    err = {"w": jnp.zeros((32, 32), jnp.float32)}
    for i in range(50):
        g = {"w": jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32))}
        dq, err = compress_grads_int8(g, err)
        g_sum += np.asarray(g["w"])
        dq_sum += np.asarray(dq["w"])
    resid = np.linalg.norm(dq_sum - g_sum) / np.linalg.norm(g_sum)
    assert resid < 0.01, resid  # residual = current error feedback only


def test_grad_compression_single_step_quantization_error_small(rng):
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    err0 = {"w": jnp.zeros((64, 64), jnp.float32)}
    dq, err = compress_grads_int8(g, err0)
    rel = float(jnp.linalg.norm(dq["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.01


def test_train_step_with_grad_compression_runs():
    cfg, params = _tiny()
    tcfg = TrainConfig(grad_compression="int8_ef")
    state = init_train_state(params, tcfg)
    assert "grad_error" in state
    step = jax.jit(make_train_step(cfg, tcfg))
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size, batch=4,
                                   seq_len=8))
    state, m = step(state, data.batch_at(0))
    assert np.isfinite(float(m["loss"]))


# ---------------------------------------------------------------------------
# Data pipeline.
# ---------------------------------------------------------------------------

def test_data_random_access_deterministic():
    cfg = DataConfig(vocab_size=100, batch=4, seq_len=16, seed=9)
    p1, p2 = DataPipeline(cfg), DataPipeline(cfg)
    b1, b2 = p1.batch_at(17), p2.batch_at(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_data_labels_shifted():
    cfg = DataConfig(vocab_size=50, batch=2, seq_len=8, seed=0)
    b = DataPipeline(cfg).batch_at(0)
    assert b["tokens"].shape == (2, 8) and b["labels"].shape == (2, 8)


def test_data_markov_learnable_structure():
    """Markov stream must be predictable: successor entropy << uniform."""
    cfg = DataConfig(vocab_size=64, batch=64, seq_len=32, seed=1)
    b = DataPipeline(cfg).batch_at(0)
    toks = np.asarray(b["tokens"])
    # count bigram diversity: following tokens concentrate on few successors
    from collections import defaultdict
    succ = defaultdict(set)
    for row in toks:
        for a, c in zip(row[:-1], row[1:]):
            succ[int(a)].add(int(c))
    avg_succ = np.mean([len(v) for v in succ.values()])
    assert avg_succ < 40  # uniform would approach 60+ distinct successors
