"""Per-architecture smoke tests (reduced configs) + model-math invariants.

Every assigned arch: instantiate the smoke config, run one forward and one
train step on CPU, assert output shapes + no NaNs (deliverable f).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import all_archs, get_config, ASSIGNED_ARCHS
from repro.models import lm as LM
from repro.models import encdec as ED
from repro.train.steps import TrainConfig, make_train_step, init_train_state

B, T = 2, 16


def _batch_for(cfg):
    toks = jnp.ones((B, T), jnp.int32)
    if cfg.family == "encdec":
        return {"enc_embeds": jnp.ones((B, T, cfg.d_model), jnp.float32) * 0.1,
                "tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        n_img = 4
        # labels cover the text positions only (logits are sliced past the
        # image embeds in the loss)
        return {"tokens": toks,
                "embeds": jnp.ones((B, n_img, cfg.d_model), jnp.float32) * .1,
                "labels": toks}
    return {"tokens": toks, "labels": toks}


def _init(cfg, key):
    if cfg.family == "encdec":
        return ED.init_encdec(key, cfg, jnp.float32)
    return LM.init_lm(key, cfg, jnp.float32)


def _forward(params, cfg, batch):
    if cfg.family == "encdec":
        logits, caches = ED.forward(params, cfg, batch["enc_embeds"],
                                    batch["tokens"])
        return logits, caches
    logits, caches, _ = LM.forward(params, cfg, batch.get("tokens"),
                                   embeds=batch.get("embeds"))
    return logits, caches


@pytest.mark.parametrize("arch_id", sorted(all_archs()))
def test_smoke_forward(arch_id, key):
    entry = get_config(arch_id)
    cfg = entry.smoke
    params = _init(cfg, key)
    batch = _batch_for(cfg)
    logits, _ = _forward(params, cfg, batch)
    # vlm: logits cover the prepended image embeds + text positions
    t_expect = T + batch["embeds"].shape[1] if cfg.family == "vlm" else T
    assert logits.shape == (B, t_expect, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), arch_id


@pytest.mark.parametrize("arch_id", sorted(all_archs()))
def test_smoke_train_step(arch_id, key):
    cfg = get_config(arch_id).smoke
    params = _init(cfg, key)
    tcfg = TrainConfig(logits_chunk=8)
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    state, metrics = step(state, _batch_for(cfg))
    assert np.isfinite(float(metrics["loss"])), arch_id
    assert float(metrics["grad_norm"]) > 0.0, arch_id


def test_assigned_archs_all_registered():
    assert len(ASSIGNED_ARCHS) == 10
    for a in ASSIGNED_ARCHS:
        assert get_config(a).full is not None


@pytest.mark.parametrize("arch_id", ASSIGNED_ARCHS)
def test_full_config_matches_assignment(arch_id):
    """Spot-check the published numbers the assignment pins."""
    cfg = get_config(arch_id).full
    expect = {
        "seamless-m4t-medium": dict(d_model=1024, n_heads=16, d_ff=4096,
                                    vocab_size=256206),
        "mamba2-2.7b": dict(n_layers=64, d_model=2560, vocab_size=50280,
                            ssm_state=128),
        "qwen3-4b": dict(n_layers=36, d_model=2560, n_heads=32,
                         n_kv_heads=8, d_ff=9728, vocab_size=151936),
        "llama3-405b": dict(n_layers=126, d_model=16384, n_heads=128,
                            n_kv_heads=8, d_ff=53248, vocab_size=128256),
        "internlm2-1.8b": dict(n_layers=24, d_model=2048, n_heads=16,
                               n_kv_heads=8, d_ff=8192, vocab_size=92544),
        "qwen2-7b": dict(n_layers=28, d_model=3584, n_heads=28,
                         n_kv_heads=4, d_ff=18944, vocab_size=152064),
        "deepseek-v2-lite-16b": dict(n_layers=27, d_model=2048,
                                     vocab_size=102400, n_experts=64,
                                     top_k=6, moe_d_ff=1408, kv_lora_rank=512),
        "kimi-k2-1t-a32b": dict(n_layers=61, d_model=7168, n_heads=64,
                                vocab_size=163840, n_experts=384, top_k=8),
        "internvl2-2b": dict(n_layers=24, d_model=2048, n_heads=16,
                             n_kv_heads=8, d_ff=8192, vocab_size=92553),
        "zamba2-1.2b": dict(n_layers=38, d_model=2048, vocab_size=32000,
                            ssm_state=64),
    }[arch_id]
    for k, v in expect.items():
        assert getattr(cfg, k) == v, (arch_id, k, getattr(cfg, k), v)


# ---------------------------------------------------------------------------
# Decode-path consistency: prefill + decode_step ≡ one long forward.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_id", ["qwen3-4b", "internlm2-1.8b",
                                     "deepseek-v2-lite-16b", "mamba2-2.7b",
                                     "zamba2-1.2b"])
def test_decode_matches_full_forward(arch_id, key):
    """logits(prefix+1 token via cache) == logits(full forward) — validates
    KV/latent/SSM caches across GQA, MLA, SSD and hybrid paths.

    MoE archs run dropless (high capacity_factor): capacity depends on the
    batch token count, so prefill+decode ≡ full only when nothing drops.
    """
    import dataclasses
    cfg = get_config(arch_id).smoke
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    params = _init(cfg, key)
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, 12), 0,
                              cfg.vocab_size)
    full_logits, _, _ = LM.forward(params, cfg, toks)

    caches = LM.init_caches(cfg, B, 12, dtype=jnp.float32)
    pre_logits, caches = LM.forward(params, cfg, toks[:, :11], caches=caches,
                                    pos=0)[0:2]
    step_logits, _, _ = LM.forward(params, cfg, toks[:, 11:12], caches=caches,
                                   pos=11)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(full_logits[:, 11]),
        rtol=2e-2, atol=2e-3)


def test_encdec_decode_matches_teacher_forcing(key):
    cfg = get_config("seamless-m4t-medium").smoke
    params = ED.init_encdec(key, cfg, jnp.float32)
    enc = jax.random.normal(jax.random.PRNGKey(3), (B, 8, cfg.d_model)) * 0.3
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, 6), 0,
                              cfg.vocab_size)
    full_logits, _ = ED.forward(params, cfg, enc, toks)

    caches = {"self": ED.init_dec_caches(cfg, B, 6, jnp.float32)}
    _, c = ED.forward(params, cfg, enc, toks[:, :5], caches=caches, pos=0)
    step_logits, _ = ED.decode_step(params, cfg, toks[:, 5:6], c, 5)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, -1]), np.asarray(full_logits[:, 5]),
        rtol=2e-2, atol=2e-3)


# ---------------------------------------------------------------------------
# SSM invariants.
# ---------------------------------------------------------------------------

def test_ssd_chunked_matches_stepwise(key):
    """Chunked SSD (training path) ≡ token-by-token recurrence (decode)."""
    from repro.models import ssm as S
    cfg = get_config("mamba2-2.7b").smoke
    b, t = 2, 12
    h, p, g, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_n_groups, cfg.ssm_state
    r = jax.random
    x = r.normal(r.PRNGKey(0), (b, t, h, p)) * 0.3
    dt = jax.nn.softplus(r.normal(r.PRNGKey(1), (b, t, h)))
    a = -jnp.exp(r.normal(r.PRNGKey(2), (h,)) * 0.3)
    b_in = r.normal(r.PRNGKey(3), (b, t, g, n)) * 0.3
    c_in = r.normal(r.PRNGKey(4), (b, t, g, n)) * 0.3

    y_chunk, s_chunk = S.ssd_chunked(x, dt, a, b_in, c_in, chunk=5)

    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for i in range(t):
        y_i, state = S.ssd_decode_step(
            x[:, i:i + 1], dt[:, i:i + 1], a, b_in[:, i:i + 1],
            c_in[:, i:i + 1], state)
        ys.append(y_i)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(state),
                               rtol=1e-3, atol=1e-3)


def test_ssd_chunk_size_invariance(key):
    from repro.models import ssm as S
    b, t, h, p, g, n = 1, 16, 2, 4, 1, 8
    r = jax.random
    x = r.normal(r.PRNGKey(0), (b, t, h, p))
    dt = jax.nn.softplus(r.normal(r.PRNGKey(1), (b, t, h)))
    a = -jnp.exp(r.normal(r.PRNGKey(2), (h,)) * 0.2)
    b_in = r.normal(r.PRNGKey(3), (b, t, g, n)) * 0.5
    c_in = r.normal(r.PRNGKey(4), (b, t, g, n)) * 0.5
    y4, s4 = S.ssd_chunked(x, dt, a, b_in, c_in, chunk=4)
    y16, s16 = S.ssd_chunked(x, dt, a, b_in, c_in, chunk=16)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y16),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s4), np.asarray(s16),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# MoE invariants.
# ---------------------------------------------------------------------------

def test_moe_expert_scan_matches_vectorized(key):
    """Paper's expert-at-a-time decompression path ≡ vectorized experts."""
    import dataclasses
    from repro.models import layers as L
    cfg = get_config("deepseek-v2-lite-16b").smoke
    p = L.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.5
    y_vec, aux_vec = L.apply_moe(p, x, cfg)
    cfg_scan = dataclasses.replace(cfg, moe_expert_scan=True)
    y_scan, aux_scan = L.apply_moe(p, x, cfg_scan)
    np.testing.assert_allclose(np.asarray(y_vec), np.asarray(y_scan),
                               rtol=1e-4, atol=1e-5)
    assert float(aux_vec) == pytest.approx(float(aux_scan))


def test_moe_aux_loss_balanced_vs_collapsed(key):
    """Aux loss must rank a collapsed router above a uniform one."""
    from repro.models import layers as L
    cfg = get_config("deepseek-v2-lite-16b").smoke
    p = L.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    _, aux_uniform = L.apply_moe(p, x, cfg)
    # collapse: router sends everything to expert 0
    p_bad = dict(p)
    router = np.zeros(p["router"].shape, np.float32)
    router[0] = 5.0
    p_bad["router"] = jnp.asarray(router)
    _, aux_collapsed = L.apply_moe(p_bad, x, cfg)
    assert float(aux_collapsed) > float(aux_uniform)


def test_n_params_analytic_close_to_actual(key):
    """Analytic count (used for MODEL_FLOPS) within 2% of real leaf count."""
    for arch_id in ["qwen3-4b", "internlm2-1.8b"]:
        cfg = get_config(arch_id).smoke
        params = LM.init_lm(key, cfg, jnp.float32)
        actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
        analytic = cfg.n_params()
        assert abs(actual - analytic) / actual < 0.02, (arch_id, actual,
                                                        analytic)


def test_int8_kv_cache_decode_close_to_fp(key):
    """Beyond-paper: int8 KV cache (paper's quantizer on the cache) keeps
    decode logits close to the fp-cache path."""
    import dataclasses
    cfg = get_config("qwen3-4b").smoke
    params = _init(cfg, key)
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, 12), 0,
                              cfg.vocab_size)

    def run(cfg_):
        caches = LM.init_caches(cfg_, B, 12, dtype=jnp.float32)
        _, caches, _ = LM.forward(params, cfg_, toks[:, :11], caches=caches,
                                  pos=0)
        logits, _, _ = LM.forward(params, cfg_, toks[:, 11:12], caches=caches,
                                  pos=11)
        return np.asarray(logits[:, 0])

    fp = run(cfg)
    q8 = run(dataclasses.replace(cfg, kv_cache_bits=8))
    # int8 cache: small logit perturbation, same top-1 on a trained-free net
    err = np.abs(fp - q8).max() / (np.abs(fp).max() + 1e-9)
    assert err < 0.05, err
    assert (fp.argmax(-1) == q8.argmax(-1)).mean() > 0.9


def test_int8_kv_cache_halves_bytes(key):
    import dataclasses
    cfg = get_config("qwen3-4b").smoke
    c16 = LM.init_caches(cfg, 2, 32, dtype=jnp.bfloat16)
    c8 = LM.init_caches(dataclasses.replace(cfg, kv_cache_bits=8), 2, 32,
                        dtype=jnp.bfloat16)
    b16 = sum(x.nbytes for x in jax.tree_util.tree_leaves(c16))
    b8 = sum(x.nbytes for x in jax.tree_util.tree_leaves(c8))
    assert b8 < 0.7 * b16, (b8, b16)
