"""Quantization core — unit + property tests (paper §3)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis")  # property tests; skip when absent
from hypothesis import given, settings, strategies as st

from repro.core.quant import (QuantConfig, quantize, dequantize, fake_quant,
                              quantization_error, TernaryTensor)
from repro.core import gptq


BITS = [2, 4, 6, 8]


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("granularity", ["per_tensor", "per_channel",
                                         "per_group"])
def test_roundtrip_error_bound(bits, granularity, rng):
    """|x - Q(x)| <= scale/2 elementwise — the defining affine-quant bound."""
    x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    cfg = QuantConfig(bits=bits, granularity=granularity, group_size=32)
    qt = quantize(x, cfg)
    xr = dequantize(qt)
    assert xr.shape == x.shape and xr.dtype == x.dtype
    err = jnp.abs(x - xr)
    # scale may be per-tensor/channel/group; bound with its max
    assert float(err.max()) <= float(qt.scale.max()) / 2 + 1e-6


def test_more_bits_less_error(rng):
    x = jnp.asarray(rng.normal(size=(32, 128)).astype(np.float32))
    errs = [float(quantization_error(x, QuantConfig(bits=b)))
            for b in BITS]
    assert errs == sorted(errs, reverse=True), errs


def test_paper_per_tensor_zero_point_integer(rng):
    """Paper's find_params: zero = round(-min/scale) is an integer code."""
    x = jnp.asarray(rng.normal(size=(512,)).astype(np.float32))
    qt = quantize(x, QuantConfig(bits=8, granularity="per_tensor"))
    assert float(qt.zero[0]) == round(float(qt.zero[0]))


def test_ternary_matches_paper_semantics(rng):
    """Paper Listing 1 maxq<0 branch: x > scale/2 -> scale; x < zero/2 -> zero."""
    x = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    cfg = QuantConfig(bits=1.5)
    qt = quantize(x, cfg)
    assert isinstance(qt, TernaryTensor)
    xr = dequantize(qt)
    xmax, xmin = float(jnp.max(x)), float(jnp.min(x))
    expect = np.where(np.asarray(x) > xmax / 2, xmax,
                      np.where(np.asarray(x) < xmin / 2, xmin, 0.0))
    np.testing.assert_allclose(np.asarray(xr), expect, rtol=1e-6)


def test_ternary_high_sparsity_on_gaussian(rng):
    """QMoE's premise: ternary quantization of ~N(0,1) is mostly zeros."""
    x = jnp.asarray(rng.normal(size=(256, 256)).astype(np.float32))
    qt = quantize(x, QuantConfig(bits=1.5))
    sparsity = float(jnp.mean(dequantize(qt) == 0.0))
    assert sparsity > 0.85  # paper: "nearly ninety percent"


def test_int8_near_zero_sparsity(rng):
    """Paper §2.5: our 8-bit models have 'close to zero' sparsity."""
    x = jnp.asarray(rng.normal(size=(256, 256)).astype(np.float32))
    qt = quantize(x, QuantConfig(bits=8, granularity="per_channel"))
    sparsity = float(jnp.mean(dequantize(qt) == 0.0))
    assert sparsity < 0.05


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 8), cols=st.integers(1, 65),
       bits=st.sampled_from([4, 8]),
       seed=st.integers(0, 2**16))
def test_property_codes_in_range_and_shape(rows, cols, bits, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(rows, cols)).astype(np.float32) *
                    r.uniform(0.01, 10))
    cfg = QuantConfig(bits=bits, granularity="per_channel")
    qt = quantize(x, cfg)
    vals = np.asarray(qt.values)
    assert vals.min() >= 0 and vals.max() <= 2 ** bits - 1
    assert dequantize(qt).shape == (rows, cols)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_quantize_idempotent(seed):
    """fake_quant(fake_quant(x)) == fake_quant(x): grid points are fixed."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(8, 32)).astype(np.float32))
    cfg = QuantConfig(bits=8, granularity="per_channel")
    y1 = fake_quant(x, cfg)
    y2 = fake_quant(y1, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-6)


def test_constant_rows_stable(rng):
    x = jnp.ones((4, 32)) * 3.0
    qt = quantize(x, QuantConfig(bits=8, granularity="per_channel"))
    assert np.isfinite(np.asarray(dequantize(qt))).all()


# ---------------------------------------------------------------------------
# GPTQ
# ---------------------------------------------------------------------------

def _calib(rng, n, d, correlated=True):
    if correlated:
        basis = rng.normal(size=(d, d // 4)).astype(np.float32)
        z = rng.normal(size=(n, d // 4)).astype(np.float32)
        return jnp.asarray(z @ basis.T + 0.05 * rng.normal(size=(n, d)))
    return jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))


def test_gptq_beats_naive_on_task_loss(rng):
    """GPTQ minimizes tr(dW H dW'); on correlated activations it must beat
    round-to-nearest on that objective (paper §3's reason to use it)."""
    d, out = 64, 32
    w = jnp.asarray(rng.normal(size=(out, d)).astype(np.float32))
    xs = [_calib(rng, 256, d) for _ in range(4)]
    h = gptq.init_hessian(d)
    for x in xs:
        h = gptq.accumulate_hessian(h, x)
    cfg = QuantConfig(bits=4, granularity="per_channel")
    qt_gptq = gptq.gptq_quantize(w, h, cfg)
    qt_rtn = quantize(w, cfg)
    e_gptq = float(gptq.gptq_layer_error(w, qt_gptq, h))
    e_rtn = float(gptq.gptq_layer_error(w, qt_rtn, h))
    assert e_gptq < e_rtn * 0.9, (e_gptq, e_rtn)


def test_gptq_8bit_high_fidelity(rng):
    d, out = 32, 16
    w = jnp.asarray(rng.normal(size=(out, d)).astype(np.float32))
    xs = [_calib(rng, 128, d)]
    qt = gptq.calibrate_and_quantize(w, xs, QuantConfig(bits=8))
    rel = float(jnp.linalg.norm(dequantize(qt) - w) / jnp.linalg.norm(w))
    assert rel < 0.01, rel


def test_gptq_dead_columns(rng):
    """Columns with no calibration signal must not produce NaNs."""
    d, out = 16, 8
    w = jnp.asarray(rng.normal(size=(out, d)).astype(np.float32))
    x = np.array(_calib(rng, 64, d, correlated=False))
    x[:, 3] = 0.0
    h = gptq.accumulate_hessian(gptq.init_hessian(d), jnp.asarray(x))
    qt = gptq.gptq_quantize(w, h, QuantConfig(bits=8))
    assert np.isfinite(np.asarray(dequantize(qt))).all()


def test_gptq_codes_layout_matches_quantlinear(rng):
    from repro.core.compressed import quantize_linear
    w = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    h = gptq.accumulate_hessian(gptq.init_hessian(16), _calib(rng, 64, 16))
    qt = gptq.gptq_quantize(w, h, QuantConfig(bits=8))
    assert qt.values.shape == (8, 16)
    assert qt.scale.shape == (8, 1)
