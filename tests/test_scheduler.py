"""Continuous-batching engine — slot lifecycle, paging, and parity.

The request-level API's acceptance contract:

  * every request served through ``serve.Engine`` — whenever it arrived,
    whichever slot it landed in, whoever its co-tenants were — yields
    tokens **bitwise-equal** to a one-shot ``engine.generate`` of the same
    prompt at the pool's cache length;
  * requests join a *running* decode loop (mid-decode admission), finish
    independently (EOS or budget), and free their slot + pages for queued
    requests — with no stale KV bleeding across page reuse;
  * one ``generate_step`` trace serves the whole mixed trace (admissions
    and completions are traced-value changes, never retraces);
  * the degradation ladder covers the scheduler's jitted steps via
    ``ResilientEngine.scheduler()``.

Plus the satellite seams: the ``Impl`` enum as the one home for impl
strings, and ``ServeContext`` deprecating the loose ``lut=``/``mesh=``
kwargs.
"""
import dataclasses
import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import CompressionPolicy
from repro.kernels import ops
from repro.models import lm as LM
from repro.serve import engine as engine_mod
from repro.serve.context import ServeContext
from repro.serve.engine import build_serve_params, generate
from repro.serve.kv_cache import PagedKVPool
from repro.serve.resilience import (FALLBACK_COUNTS, ResiliencePolicy,
                                    ResilientEngine)
from repro.serve.scheduler import Engine, Request
from repro.testing import FaultInjector


@pytest.fixture(scope="module")
def served():
    """(cfg, ServeState, ctx) for the dense smoke config."""
    cfg = get_config("llama3.2-1b").smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    st = build_serve_params(
        params, CompressionPolicy(mode="compressed", min_weight_size=1024))
    return cfg, st, ServeContext.from_state(cfg, st)


def _prompts(cfg, n, seed=100):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size,
                        int(rng.randint(4, 12))).astype(np.int32)
            for _ in range(n)]


def _ref(st, cfg, ctx, prompt, max_new, max_len):
    return np.asarray(generate(st.params, cfg, prompt[None, :], ctx=ctx,
                               max_new=max_new, max_len=max_len))[0]


# -- parity ------------------------------------------------------------

def test_single_request_bitwise_parity(served):
    cfg, st, ctx = served
    eng = Engine(ctx, st.params, n_slots=2, max_len=24)
    [p] = _prompts(cfg, 1)
    eng.submit(Request(tokens=p, max_new=5))
    comps = eng.drain()
    assert len(comps) == 1 and comps[0].finished == "max_new"
    np.testing.assert_array_equal(
        comps[0].tokens, _ref(st, cfg, ctx, p, 5, eng.pool.max_len))


def test_mixed_trace_staggered_arrivals_bitwise_parity(served):
    """The acceptance bar: 8 overlapping requests, staggered arrivals,
    varied prompt/decode lengths, 3 slots — every output bitwise-equal to
    one-shot generate, with occupancy > 1 and mid-decode admissions."""
    cfg, st, ctx = served
    eng = Engine(ctx, st.params, n_slots=3, max_len=20)
    prompts = _prompts(cfg, 8)
    rng = np.random.RandomState(0)
    max_news = rng.randint(3, 9, 8)
    arrivals = np.concatenate([[0], np.cumsum(rng.poisson(1.5, 7))])
    submitted = 0
    while submitted < 8 or eng.health()["occupied"] or eng.health()["queued"]:
        while submitted < 8 and eng.steps >= arrivals[submitted]:
            eng.submit(Request(tokens=prompts[submitted],
                               max_new=int(max_news[submitted]),
                               rid=submitted))
            submitted += 1
        eng.step()
    h = eng.health()
    assert h["completed"] == 8
    assert h["occupancy_max"] > 1
    assert h["joined_mid_decode"] >= 1
    by_rid = {c.rid: c for c in eng.completions}
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(
            by_rid[i].tokens,
            _ref(st, cfg, ctx, p, int(max_news[i]), eng.pool.max_len),
            err_msg=f"request {i} diverged from one-shot generate")


def test_one_trace_serves_the_whole_trace(served):
    """Admissions/completions are traced-value changes: a full multi-
    admission drain runs on ONE generate_step trace (and one prefill)."""
    cfg, st, ctx = served
    cfgf = dataclasses.replace(cfg, name=cfg.name + "-sched-trace")
    eng = Engine(ctx.with_cfg(cfgf), st.params, n_slots=2, max_len=20)
    engine_mod.TRACE_COUNTS.clear()
    for i, p in enumerate(_prompts(cfg, 4)):
        eng.submit(Request(tokens=p, max_new=4, rid=i))
    eng.drain()
    assert engine_mod.TRACE_COUNTS["generate_step"] == 1, \
        dict(engine_mod.TRACE_COUNTS)
    assert len(eng.completions) == 4


# -- slot lifecycle ----------------------------------------------------

def test_completion_frees_slot_and_queue_refills(served):
    """More requests than slots: early finishers free their slot, queued
    requests join the *running* loop, pages recycle, outputs stay exact."""
    cfg, st, ctx = served
    eng = Engine(ctx, st.params, n_slots=2, max_len=16)
    prompts = _prompts(cfg, 5, seed=7)
    max_news = [2, 6, 3, 5, 4]
    for i, p in enumerate(prompts):
        eng.submit(Request(tokens=p, max_new=max_news[i], rid=i))
    n_pages0 = len(eng.pool.free_pages)
    eng.drain()
    h = eng.health()
    assert h["completed"] == 5
    assert h["joined_mid_decode"] >= 1          # refill joined mid-stream
    assert len(eng.pool.free_pages) == n_pages0  # all pages returned
    by_rid = {c.rid: c for c in eng.completions}
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(
            by_rid[i].tokens,
            _ref(st, cfg, ctx, p, max_news[i], eng.pool.max_len),
            err_msg=f"request {i}: stale KV after page reuse?")


def test_page_reuse_no_stale_kv(served):
    """Serve the same prompt before and after other tenants churned
    through the pool's pages (LIFO reuse): outputs must be identical."""
    cfg, st, ctx = served
    eng = Engine(ctx, st.params, n_slots=2, max_len=16)
    [p0, p1, p2] = _prompts(cfg, 3, seed=11)
    eng.submit(Request(tokens=p0, max_new=5, rid=0))
    first = eng.drain()[0].tokens
    # churn: different prompts write different KV into the same pages
    eng.submit(Request(tokens=p1, max_new=6, rid=1))
    eng.submit(Request(tokens=p2, max_new=4, rid=2))
    eng.drain()
    eng.submit(Request(tokens=p0, max_new=5, rid=3))
    again = eng.drain()[0].tokens
    np.testing.assert_array_equal(first, again)


def test_eos_stops_early_and_frees_slot(served):
    """A request whose eos_id matches a mid-stream token finishes early
    with finished='eos', truncated at (and including) the EOS token."""
    cfg, st, ctx = served
    eng = Engine(ctx, st.params, n_slots=2, max_len=24)
    [p] = _prompts(cfg, 1, seed=3)
    full = Engine(ctx, st.params, n_slots=1, max_len=24)
    full.submit(Request(tokens=p, max_new=6))
    ref = full.drain()[0].tokens
    gen = ref[len(p):]
    eos = int(gen[2])                      # a token generated mid-stream
    eng.submit(Request(tokens=p, max_new=6, eos_id=eos))
    [c] = eng.drain()
    assert c.finished == "eos"
    assert c.n_generated <= 6 and c.tokens[-1] == eos
    np.testing.assert_array_equal(c.tokens, ref[:len(p) + c.n_generated])
    assert eng.health()["occupied"] == 0
    assert len(eng.pool.free_pages) == eng.pool.n_pages


def test_sampling_deterministic_per_request(served):
    """temperature > 0: per-request PRNG (seed folded with absolute
    position) makes outputs reproducible run to run."""
    cfg, st, ctx = served
    outs = []
    for _ in range(2):
        eng = Engine(ctx, st.params, n_slots=2, max_len=20)
        for i, p in enumerate(_prompts(cfg, 2, seed=5)):
            eng.submit(Request(tokens=p, max_new=5, temperature=0.8,
                               seed=42 + i, rid=i))
        eng.drain()
        outs.append({c.rid: c.tokens for c in eng.completions})
    for rid in outs[0]:
        np.testing.assert_array_equal(outs[0][rid], outs[1][rid])


def test_submit_validates(served):
    cfg, st, ctx = served
    eng = Engine(ctx, st.params, n_slots=1, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(tokens=np.arange(10), max_new=10))
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(tokens=np.zeros((0,), np.int32)))


# -- cache paging across model families --------------------------------

def test_moe_dropless_parity():
    """MoE configs page too (stacked + per-layer 'first' caches, MLA
    latent planes).  Expert-capacity drops depend on batch size, so exact
    parity needs the dropless regime (capacity_factor >= E / top_k)."""
    cfg = get_config("deepseek-v2-lite-16b").smoke
    cfg = dataclasses.replace(cfg, name=cfg.name + "-sched-dropless",
                              capacity_factor=float(cfg.n_experts)
                              / cfg.top_k)
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    st = build_serve_params(
        params, CompressionPolicy(mode="compressed", min_weight_size=1024))
    ctx = ServeContext.from_state(cfg, st)
    eng = Engine(ctx, st.params, n_slots=2, max_len=16)
    prompts = _prompts(cfg, 3, seed=9)
    for i, p in enumerate(prompts):
        eng.submit(Request(tokens=p, max_new=3, rid=i))
    eng.drain()
    assert eng.health()["occupancy_max"] > 1
    by_rid = {c.rid: c for c in eng.completions}
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(
            by_rid[i].tokens, _ref(st, cfg, ctx, p, 3, eng.pool.max_len))


def test_recurrent_families_rejected():
    """ssm state has no time axis to page — the pool must refuse loudly
    at construction, not corrupt state silently."""
    cfg = get_config("mamba2-2.7b").smoke
    with pytest.raises(ValueError):
        PagedKVPool(cfg, 2, 16)


# -- resilience composition --------------------------------------------

def test_resilient_scheduler_ladder_on_ingraph_fault(served):
    """A persistent fused-kernel fault inside the jitted generate_step:
    the guard walks the ladder, re-traces unfused, and the served outputs
    equal the clean run's."""
    cfg, st, _ = served
    prompts = _prompts(cfg, 2, seed=13)

    def run(cfg_run, inject):
        reng = ResilientEngine(cfg_run, st,
                               policy=ResiliencePolicy(max_retries=0))
        eng = reng.scheduler(n_slots=2, max_len=16)
        for i, p in enumerate(prompts):
            eng.submit(Request(tokens=p, max_new=4, rid=i))
        if inject:
            with FaultInjector().decode_fault(nth=1):
                eng.drain()
        else:
            eng.drain()
        return reng, {c.rid: c.tokens for c in eng.completions}

    _, clean = run(dataclasses.replace(cfg, name=cfg.name + "-rs-clean"),
                   False)
    reng, faulty = run(dataclasses.replace(cfg, name=cfg.name + "-rs-fault"),
                       True)
    assert reng.last_rung == "unfused"
    assert FALLBACK_COUNTS["unfused"] >= 1
    for rid in clean:
        np.testing.assert_array_equal(clean[rid], faulty[rid])


# -- sharded serving ---------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (tier1-multidevice CI job)")
def test_scheduler_sharded_parity_8dev():
    """2×4 (data, model) mesh: the scheduler's generate_step traces under
    the mesh — the compressed matmuls take the shard-mapped fused path
    (dispatch probe) — and serving a request next to a co-tenant is
    bitwise-identical to serving it alone through the same pool.  (A
    mesh-less run is NOT the reference: cross-device reduction order
    changes the bf16 floats, so the invariance is asserted *within* the
    mesh, where both runs share one trace.)"""
    from repro.sharding import partition as PT
    cfg = get_config("llama3.2-1b").smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    st = build_serve_params(
        params, CompressionPolicy(mode="compressed", min_weight_size=1024),
        model_shards=4)                    # tiles divide the model axis
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    specs = PT.make_param_specs(st.params, mesh,
                                PT.ShardingConfig(mode="serve"))
    sp = jax.device_put(st.params, PT.to_named(specs, mesh))
    lut = jax.device_put(
        st.lut, jax.NamedSharding(mesh, jax.sharding.PartitionSpec()))
    prompts = _prompts(cfg, 2, seed=17)

    cfgm = dataclasses.replace(cfg, name=cfg.name + "-sched-mesh")
    ctxm = ServeContext(cfg=cfgm, mesh=mesh, lut=lut)
    with mesh, PT.active_mesh(mesh):
        ops.DISPATCH_COUNTS.clear()
        solo = {}
        for i, p in enumerate(prompts):
            eng = Engine(ctxm, sp, n_slots=2, max_len=16)
            eng.submit(Request(tokens=p, max_new=4, rid=i))
            eng.drain()
            solo[i] = eng.completions[0].tokens
        assert any(k.endswith("fused_shard_map")
                   for k in ops.DISPATCH_COUNTS), dict(ops.DISPATCH_COUNTS)
        eng = Engine(ctxm, sp, n_slots=2, max_len=16)
        for i, p in enumerate(prompts):
            eng.submit(Request(tokens=p, max_new=4, rid=i))
        eng.drain()
    both = {c.rid: c.tokens for c in eng.completions}
    for i in range(2):
        np.testing.assert_array_equal(
            solo[i], both[i],
            err_msg=f"request {i} changed under co-tenancy on the mesh")


# -- satellite seams ---------------------------------------------------

def test_impl_enum_is_the_one_home():
    assert ops.Impl("unfused") is ops.Impl.UNFUSED
    assert ops.Impl.UNFUSED.value == "unfused"
    assert str(ops.Impl.UNFUSED) == "unfused"          # f-string safe
    assert f"x+{ops.Impl.MATERIALIZE}" == "x+materialize"
    assert ops.VALID_IMPLS == frozenset(i.value for i in ops.Impl)
    assert ops.DEFAULT_LADDER == ResiliencePolicy().ladder
    prev = ops._DEFAULT_IMPL
    try:
        ops.set_default_impl(ops.Impl.REF)
        assert ops._DEFAULT_IMPL == "ref"
        with pytest.raises(ValueError):
            ops.set_default_impl("warp-speed")
    finally:
        ops.set_default_impl(prev)
    from repro import kernels
    assert kernels.Impl is ops.Impl


def test_serve_context_deprecates_loose_kwargs(served):
    cfg, st, ctx = served
    toks = jnp.asarray(_prompts(cfg, 1, seed=19)[0][None, :])
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        via_ctx = generate(st.params, cfg, toks, ctx=ctx, max_new=3)
    with pytest.warns(DeprecationWarning, match="ServeContext"):
        via_kw = generate(st.params, cfg, toks, lut=st.lut, max_new=3)
    np.testing.assert_array_equal(np.asarray(via_ctx), np.asarray(via_kw))
