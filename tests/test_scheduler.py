"""Continuous-batching engine — slot lifecycle, paging, and parity.

The request-level API's acceptance contract:

  * every request served through ``serve.Engine`` — whenever it arrived,
    whichever slot it landed in, whoever its co-tenants were — yields
    tokens **bitwise-equal** to a one-shot ``engine.generate`` of the same
    prompt at the pool's cache length;
  * requests join a *running* decode loop (mid-decode admission), finish
    independently (EOS or budget), and free their slot + pages for queued
    requests — with no stale KV bleeding across page reuse;
  * one ``generate_step`` trace serves the whole mixed trace (admissions
    and completions are traced-value changes, never retraces);
  * the degradation ladder covers the scheduler's jitted steps via
    ``ResilientEngine.scheduler()``.

The request-level robustness layer rides the same contract:

  * overload is *accounted*, never unbounded: a full bounded queue sheds
    per policy, TTL'd requests expire queued or in-flight — always as
    completions with explicit reasons;
  * a poisoned request is quarantined alone: the bisect isolates exactly
    one culprit from a mixed batch (reusing the existing trace), and the
    survivors — like preempted-then-resumed victims — finish bitwise-equal
    to an uninterrupted run;
  * page pressure (overcommitted ``n_pages``, injected alloc failure)
    preempts strictly-lower-priority work, never deadlocks admission.

Plus the satellite seams: the ``Impl`` enum as the one home for impl
strings, and ``ServeContext`` deprecating the loose ``lut=``/``mesh=``
kwargs.
"""
import dataclasses
import warnings

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import CompressionPolicy
from repro.kernels import ops
from repro.models import lm as LM
from repro.serve import engine as engine_mod
from repro.serve.context import ServeContext
from repro.serve.engine import build_serve_params, generate
from repro.serve.kv_cache import PagedKVPool, PoolError, PoolExhausted
from repro.serve.resilience import (FALLBACK_COUNTS, ResiliencePolicy,
                                    ResilientEngine)
from repro.serve.scheduler import Engine, Request
from repro.testing import FaultInjector


@pytest.fixture(scope="module")
def served():
    """(cfg, ServeState, ctx) for the dense smoke config."""
    cfg = get_config("llama3.2-1b").smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    st = build_serve_params(
        params, CompressionPolicy(mode="compressed", min_weight_size=1024))
    return cfg, st, ServeContext.from_state(cfg, st)


def _prompts(cfg, n, seed=100):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size,
                        int(rng.randint(4, 12))).astype(np.int32)
            for _ in range(n)]


def _ref(st, cfg, ctx, prompt, max_new, max_len):
    return np.asarray(generate(st.params, cfg, prompt[None, :], ctx=ctx,
                               max_new=max_new, max_len=max_len))[0]


# -- parity ------------------------------------------------------------

def test_single_request_bitwise_parity(served):
    cfg, st, ctx = served
    eng = Engine(ctx, st.params, n_slots=2, max_len=24)
    [p] = _prompts(cfg, 1)
    eng.submit(Request(tokens=p, max_new=5))
    comps = eng.drain()
    assert len(comps) == 1 and comps[0].finished == "max_new"
    np.testing.assert_array_equal(
        comps[0].tokens, _ref(st, cfg, ctx, p, 5, eng.pool.max_len))


def test_mixed_trace_staggered_arrivals_bitwise_parity(served):
    """The acceptance bar: 8 overlapping requests, staggered arrivals,
    varied prompt/decode lengths, 3 slots — every output bitwise-equal to
    one-shot generate, with occupancy > 1 and mid-decode admissions."""
    cfg, st, ctx = served
    eng = Engine(ctx, st.params, n_slots=3, max_len=20)
    prompts = _prompts(cfg, 8)
    rng = np.random.RandomState(0)
    max_news = rng.randint(3, 9, 8)
    arrivals = np.concatenate([[0], np.cumsum(rng.poisson(1.5, 7))])
    submitted = 0
    while submitted < 8 or eng.health()["occupied"] or eng.health()["queued"]:
        while submitted < 8 and eng.steps >= arrivals[submitted]:
            eng.submit(Request(tokens=prompts[submitted],
                               max_new=int(max_news[submitted]),
                               rid=submitted))
            submitted += 1
        eng.step()
    h = eng.health()
    assert h["completed"] == 8
    assert h["occupancy_max"] > 1
    assert h["joined_mid_decode"] >= 1
    by_rid = {c.rid: c for c in eng.completions}
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(
            by_rid[i].tokens,
            _ref(st, cfg, ctx, p, int(max_news[i]), eng.pool.max_len),
            err_msg=f"request {i} diverged from one-shot generate")


def test_one_trace_serves_the_whole_trace(served):
    """Admissions/completions are traced-value changes: a full multi-
    admission drain runs on ONE generate_step trace (and one prefill)."""
    cfg, st, ctx = served
    cfgf = dataclasses.replace(cfg, name=cfg.name + "-sched-trace")
    eng = Engine(ctx.with_cfg(cfgf), st.params, n_slots=2, max_len=20)
    engine_mod.TRACE_COUNTS.clear()
    for i, p in enumerate(_prompts(cfg, 4)):
        eng.submit(Request(tokens=p, max_new=4, rid=i))
    eng.drain()
    assert engine_mod.TRACE_COUNTS["generate_step"] == 1, \
        dict(engine_mod.TRACE_COUNTS)
    assert len(eng.completions) == 4


# -- slot lifecycle ----------------------------------------------------

def test_completion_frees_slot_and_queue_refills(served):
    """More requests than slots: early finishers free their slot, queued
    requests join the *running* loop, pages recycle, outputs stay exact."""
    cfg, st, ctx = served
    eng = Engine(ctx, st.params, n_slots=2, max_len=16)
    prompts = _prompts(cfg, 5, seed=7)
    max_news = [2, 6, 3, 5, 4]
    for i, p in enumerate(prompts):
        eng.submit(Request(tokens=p, max_new=max_news[i], rid=i))
    n_pages0 = len(eng.pool.free_pages)
    eng.drain()
    h = eng.health()
    assert h["completed"] == 5
    assert h["joined_mid_decode"] >= 1          # refill joined mid-stream
    assert len(eng.pool.free_pages) == n_pages0  # all pages returned
    by_rid = {c.rid: c for c in eng.completions}
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(
            by_rid[i].tokens,
            _ref(st, cfg, ctx, p, max_news[i], eng.pool.max_len),
            err_msg=f"request {i}: stale KV after page reuse?")


def test_page_reuse_no_stale_kv(served):
    """Serve the same prompt before and after other tenants churned
    through the pool's pages (LIFO reuse): outputs must be identical."""
    cfg, st, ctx = served
    eng = Engine(ctx, st.params, n_slots=2, max_len=16)
    [p0, p1, p2] = _prompts(cfg, 3, seed=11)
    eng.submit(Request(tokens=p0, max_new=5, rid=0))
    first = eng.drain()[0].tokens
    # churn: different prompts write different KV into the same pages
    eng.submit(Request(tokens=p1, max_new=6, rid=1))
    eng.submit(Request(tokens=p2, max_new=4, rid=2))
    eng.drain()
    eng.submit(Request(tokens=p0, max_new=5, rid=3))
    again = eng.drain()[0].tokens
    np.testing.assert_array_equal(first, again)


def test_eos_stops_early_and_frees_slot(served):
    """A request whose eos_id matches a mid-stream token finishes early
    with finished='eos', truncated at (and including) the EOS token."""
    cfg, st, ctx = served
    eng = Engine(ctx, st.params, n_slots=2, max_len=24)
    [p] = _prompts(cfg, 1, seed=3)
    full = Engine(ctx, st.params, n_slots=1, max_len=24)
    full.submit(Request(tokens=p, max_new=6))
    ref = full.drain()[0].tokens
    gen = ref[len(p):]
    eos = int(gen[2])                      # a token generated mid-stream
    eng.submit(Request(tokens=p, max_new=6, eos_id=eos))
    [c] = eng.drain()
    assert c.finished == "eos"
    assert c.n_generated <= 6 and c.tokens[-1] == eos
    np.testing.assert_array_equal(c.tokens, ref[:len(p) + c.n_generated])
    assert eng.health()["occupied"] == 0
    assert len(eng.pool.free_pages) == eng.pool.n_pages


def test_sampling_deterministic_per_request(served):
    """temperature > 0: per-request PRNG (seed folded with absolute
    position) makes outputs reproducible run to run."""
    cfg, st, ctx = served
    outs = []
    for _ in range(2):
        eng = Engine(ctx, st.params, n_slots=2, max_len=20)
        for i, p in enumerate(_prompts(cfg, 2, seed=5)):
            eng.submit(Request(tokens=p, max_new=5, temperature=0.8,
                               seed=42 + i, rid=i))
        eng.drain()
        outs.append({c.rid: c.tokens for c in eng.completions})
    for rid in outs[0]:
        np.testing.assert_array_equal(outs[0][rid], outs[1][rid])


def test_submit_validates(served):
    cfg, st, ctx = served
    eng = Engine(ctx, st.params, n_slots=1, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(tokens=np.arange(10), max_new=10))
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(tokens=np.zeros((0,), np.int32)))


# -- cache paging across model families --------------------------------

def test_moe_dropless_parity():
    """MoE configs page too (stacked + per-layer 'first' caches, MLA
    latent planes).  Expert-capacity drops depend on batch size, so exact
    parity needs the dropless regime (capacity_factor >= E / top_k)."""
    cfg = get_config("deepseek-v2-lite-16b").smoke
    cfg = dataclasses.replace(cfg, name=cfg.name + "-sched-dropless",
                              capacity_factor=float(cfg.n_experts)
                              / cfg.top_k)
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    st = build_serve_params(
        params, CompressionPolicy(mode="compressed", min_weight_size=1024))
    ctx = ServeContext.from_state(cfg, st)
    eng = Engine(ctx, st.params, n_slots=2, max_len=16)
    prompts = _prompts(cfg, 3, seed=9)
    for i, p in enumerate(prompts):
        eng.submit(Request(tokens=p, max_new=3, rid=i))
    eng.drain()
    assert eng.health()["occupancy_max"] > 1
    by_rid = {c.rid: c for c in eng.completions}
    for i, p in enumerate(prompts):
        np.testing.assert_array_equal(
            by_rid[i].tokens, _ref(st, cfg, ctx, p, 3, eng.pool.max_len))


def test_recurrent_families_rejected():
    """ssm state has no time axis to page — the pool must refuse loudly
    at construction, not corrupt state silently."""
    cfg = get_config("mamba2-2.7b").smoke
    with pytest.raises(ValueError):
        PagedKVPool(cfg, 2, 16)


# -- resilience composition --------------------------------------------

def test_resilient_scheduler_ladder_on_ingraph_fault(served):
    """A persistent fused-kernel fault inside the jitted generate_step:
    the guard walks the ladder, re-traces unfused, and the served outputs
    equal the clean run's."""
    cfg, st, _ = served
    prompts = _prompts(cfg, 2, seed=13)

    def run(cfg_run, inject):
        reng = ResilientEngine(cfg_run, st,
                               policy=ResiliencePolicy(max_retries=0))
        eng = reng.scheduler(n_slots=2, max_len=16)
        for i, p in enumerate(prompts):
            eng.submit(Request(tokens=p, max_new=4, rid=i))
        if inject:
            with FaultInjector().decode_fault(nth=1):
                eng.drain()
        else:
            eng.drain()
        return reng, {c.rid: c.tokens for c in eng.completions}

    _, clean = run(dataclasses.replace(cfg, name=cfg.name + "-rs-clean"),
                   False)
    reng, faulty = run(dataclasses.replace(cfg, name=cfg.name + "-rs-fault"),
                       True)
    assert reng.last_rung == "unfused"
    assert FALLBACK_COUNTS["unfused"] >= 1
    for rid in clean:
        np.testing.assert_array_equal(clean[rid], faulty[rid])


# -- admission control (overload is accounted, never unbounded) --------

def test_bounded_queue_sheds_per_policy(served):
    cfg, st, ctx = served
    [p] = _prompts(cfg, 1, seed=23)
    # reject-new: the overflowing submission sheds
    eng = Engine(ctx, st.params, n_slots=1, max_len=16, max_queue=1)
    r0 = eng.submit(Request(tokens=p, max_new=1))
    r1 = eng.submit(Request(tokens=p, max_new=1))
    assert [c.rid for c in eng.completions] == [r1]
    assert eng.completions[0].finished == "shed"
    assert eng.completions[0].n_generated == 0
    assert eng.health()["queued"] == 1 and eng.health()["shed"] == 1
    # drop-oldest: the queue head sheds, the new submission queues
    eng = Engine(ctx, st.params, n_slots=1, max_len=16, max_queue=1,
                 shed_policy="drop-oldest")
    r0 = eng.submit(Request(tokens=p, max_new=1))
    r1 = eng.submit(Request(tokens=p, max_new=1))
    assert [c.rid for c in eng.completions] == [r0]
    assert eng.completions[0].finished == "shed"
    assert [q.req.rid for q in eng._queue] == [r1]
    assert FALLBACK_COUNTS["shed"] == 2
    with pytest.raises(ValueError, match="shed_policy"):
        Engine(ctx, st.params, shed_policy="drop-newest")


def test_request_ttl_expires_queued_and_inflight(served):
    cfg, st, ctx = served
    p = _prompts(cfg, 1, seed=25)[0][:6]
    eng = Engine(ctx, st.params, n_slots=1, max_len=16)
    eng.submit(Request(tokens=p, max_new=4, rid=0))
    eng.submit(Request(tokens=p, max_new=4, rid=1, ttl_steps=1))
    eng.step()                    # r0 takes the only slot; r1 queued
    eng.step()                    # r1's TTL passes while queued
    by_rid = {c.rid: c for c in eng.completions}
    assert by_rid[1].finished == "deadline" and by_rid[1].n_generated == 0
    eng.drain()
    # in-flight expiry: admitted, decodes, then retired mid-stream with
    # its partial output
    eng.submit(Request(tokens=p, max_new=10, rid=2, ttl_steps=3))
    eng.drain()
    c = {c.rid: c for c in eng.completions}[2]
    assert c.finished == "deadline"
    assert 0 < c.n_generated < 10
    np.testing.assert_array_equal(c.tokens[:len(p)], p)
    # engine-wide default TTL applies to requests that don't carry one
    eng = Engine(ctx, st.params, n_slots=1, max_len=16, request_ttl=0)
    eng.submit(Request(tokens=p, max_new=4, rid=3))
    eng.step()
    assert eng.completions[0].finished == "deadline"
    assert FALLBACK_COUNTS["expired"] == 3


def test_rid_collision_rejected(served):
    cfg, st, ctx = served
    [p] = _prompts(cfg, 1, seed=27)
    eng = Engine(ctx, st.params, n_slots=2, max_len=16)
    eng.submit(Request(tokens=p, max_new=1, rid=7))
    with pytest.raises(ValueError, match="rid 7 already in flight"):
        eng.submit(Request(tokens=p, max_new=1, rid=7))
    # auto-assigned rids stay ahead of caller-supplied ones
    assert eng.submit(Request(tokens=p, max_new=1)) == 8
    eng.drain()
    # a finished rid is no longer live and may be reused
    assert eng.submit(Request(tokens=p, max_new=1, rid=7)) == 7
    eng.drain()


# -- preemption + page pressure ----------------------------------------

def test_preempt_under_page_pressure_resumes_bitwise(served):
    """Overcommitted pool (2 pages back 1 of 2 slots): a priority-1
    arrival evicts the in-flight priority-0 request, which later resumes
    and still matches one-shot generate bitwise."""
    cfg, st, ctx = served
    p0 = _prompts(cfg, 1, seed=29)[0][:6]
    p1 = _prompts(cfg, 1, seed=31)[0][:6]
    eng = Engine(ctx, st.params, n_slots=2, max_len=16, page_size=8,
                 n_pages=2)
    eng.submit(Request(tokens=p0, max_new=8, rid=0))
    eng.step()                                  # r0 holds the only pages
    eng.submit(Request(tokens=p1, max_new=3, rid=1, priority=1))
    eng.drain()
    h = eng.health()
    assert h["preempted"] == 1 and h["resumed"] == 1
    assert FALLBACK_COUNTS["preempt"] == 1
    by_rid = {c.rid: c for c in eng.completions}
    assert by_rid[0].resumed == 1 and by_rid[0].finished == "max_new"
    np.testing.assert_array_equal(
        by_rid[0].tokens, _ref(st, cfg, ctx, p0, 8, eng.pool.max_len),
        err_msg="preempted+resumed request diverged from generate")
    np.testing.assert_array_equal(
        by_rid[1].tokens, _ref(st, cfg, ctx, p1, 3, eng.pool.max_len))
    # equal priority must NOT preempt (no livelock-swap): the late
    # arrival waits for pages instead
    eng = Engine(ctx, st.params, n_slots=2, max_len=16, page_size=8,
                 n_pages=2)
    eng.submit(Request(tokens=p0, max_new=4, rid=0))
    eng.step()
    eng.submit(Request(tokens=p1, max_new=2, rid=1))
    eng.step()
    assert eng.health()["preempted"] == 0
    assert eng.health()["queued"] == 1
    eng.drain()
    assert all(c.finished == "max_new" for c in eng.completions)


def test_alloc_failure_injection_both_seams(served):
    cfg, st, ctx = served
    p = _prompts(cfg, 1, seed=33)[0][:6]
    inj = FaultInjector()
    # can_alloc seam: pressure visible before prefill — admission waits
    eng = Engine(ctx, st.params, n_slots=1, max_len=16)
    eng.submit(Request(tokens=p, max_new=2, rid=0))
    with inj.alloc_failure(times=1) as probe:
        eng.step()
        assert eng.health()["queued"] == 1      # blocked, not crashed
    assert probe.executions == 1
    [c] = eng.drain()
    assert c.finished == "max_new"
    # alloc seam: post-prefill PoolExhausted — requeued at the head
    eng = Engine(ctx, st.params, n_slots=1, max_len=16)
    eng.submit(Request(tokens=p, max_new=2, rid=0))
    with inj.alloc_failure(times=1, seam="alloc") as probe:
        eng.step()
        assert eng.health()["queued"] == 1
    assert probe.executions == 1
    [c] = eng.drain()
    assert c.finished == "max_new"


def test_pool_alloc_free_invariants(served):
    cfg, _, _ = served
    pool = PagedKVPool(cfg, 2, 16, page_size=8)
    pool.alloc(0)
    with pytest.raises(PoolError, match="already owns"):
        pool.alloc(0)                           # double alloc
    n_free = len(pool.free_pages)
    pool.free(1)                                # never allocated: no-op
    assert len(pool.free_pages) == n_free
    pool.free(0)
    assert len(pool.free_pages) == pool.n_pages
    # overcommit: 2 pages back only one slot
    pool = PagedKVPool(cfg, 2, 16, page_size=8, n_pages=2)
    pool.alloc(0)
    assert not pool.can_alloc()
    with pytest.raises(PoolExhausted, match="exhausted"):
        pool.alloc(1)
    with pytest.raises(ValueError, match="cannot back even one slot"):
        PagedKVPool(cfg, 2, 16, page_size=8, n_pages=1)


def test_drain_error_carries_health_and_slot_state(served):
    """A non-converging drain must raise with the health snapshot and
    per-slot/queue rid state attached — the operator's first clue."""
    cfg, st, ctx = served
    [p] = _prompts(cfg, 1, seed=35)
    eng = Engine(ctx, st.params, n_slots=1, max_len=16)
    eng.submit(Request(tokens=p, max_new=2, rid=0))
    with FaultInjector().alloc_failure(times=1 << 30):
        with pytest.raises(RuntimeError, match="did not converge") as ei:
            eng.drain(max_steps=3)
    msg = str(ei.value)
    assert "health=" in msg and "queued rids=[0]" in msg


# -- poisoned-request quarantine ---------------------------------------

def test_quarantine_refuses_exactly_one_of_mixed_batch(served):
    """The acceptance bar: a single-slot fault in a 3-request mixed batch
    refuses exactly that request; the survivors resume and finish
    bitwise-equal to an uninterrupted run — all on ONE generate_step
    trace (the bisect's masked replays are traced-value changes)."""
    cfg, st, ctx = served
    cfgf = dataclasses.replace(cfg, name=cfg.name + "-sched-quar")
    eng = Engine(ctx.with_cfg(cfgf), st.params, n_slots=3, max_len=16)
    prompts = [p[:6] for p in _prompts(cfg, 3, seed=37)]
    for i, p in enumerate(prompts):
        eng.submit(Request(tokens=p, max_new=4, rid=i))
    engine_mod.TRACE_COUNTS.clear()
    # arm only until the quarantine fires, so the slot's next occupant
    # (a resumed survivor) decodes clean
    with FaultInjector().slot_fault(slot=1, nth=1):
        while not any(c.finished == "refused" for c in eng.completions):
            eng.step()
    eng.drain()
    assert engine_mod.TRACE_COUNTS["generate_step"] == 1, \
        dict(engine_mod.TRACE_COUNTS)
    by_rid = {c.rid: c for c in eng.completions}
    assert by_rid[1].finished == "refused"       # slot 1's tenant
    assert "poisoned" in by_rid[1].error
    assert FALLBACK_COUNTS["quarantine"] == 1
    for i in (0, 2):
        assert by_rid[i].finished == "max_new" and by_rid[i].resumed == 1
        np.testing.assert_array_equal(
            by_rid[i].tokens, _ref(st, cfg, ctx, prompts[i], 4,
                                   eng.pool.max_len),
            err_msg=f"survivor {i} diverged after quarantine resume")


def test_quarantine_after_exhausted_ladder(served):
    """Under ResilientEngine the fault must first exhaust the whole
    degradation ladder (it follows the request, not the kernel), and the
    resulting ServeRefused drives the same bisect."""
    cfg, st, _ = served
    reng = ResilientEngine(cfg, st, policy=ResiliencePolicy(max_retries=0))
    eng = reng.scheduler(n_slots=3, max_len=16)
    prompts = [p[:6] for p in _prompts(cfg, 3, seed=39)]
    for i, p in enumerate(prompts):
        eng.submit(Request(tokens=p, max_new=3, rid=i))
    with FaultInjector().slot_fault(slot=1, nth=1):
        while not any(c.finished == "refused" for c in eng.completions):
            eng.step()
    eng.drain()
    refused = [c for c in eng.completions if c.finished == "refused"]
    assert len(refused) == 1 and refused[0].rid == 1
    assert "ServeRefused" in refused[0].error
    assert FALLBACK_COUNTS["quarantine"] == 1
    survivors = [c for c in eng.completions if c.rid != 1]
    assert all(c.finished == "max_new" and c.resumed == 1
               for c in survivors)


def test_decode_fault_mid_mixed_batch_walks_ladder(served):
    """satellite: an in-graph decode_fault calibrated (via FaultProbe) to
    fire mid-decode of a 2-request mixed batch — the ladder re-traces
    unfused and the served outputs equal the clean run's bitwise; no
    request is refused, because the fallback rung genuinely recovers."""
    cfg, st, _ = served
    prompts = [p[:6] for p in _prompts(cfg, 2, seed=41)]

    def run(tag, nth):
        reng = ResilientEngine(
            dataclasses.replace(cfg, name=f"{cfg.name}-mid-{tag}"), st,
            policy=ResiliencePolicy(max_retries=0))
        eng = reng.scheduler(n_slots=2, max_len=16)
        for i, p in enumerate(prompts):
            eng.submit(Request(tokens=p, max_new=5, rid=i))
        with FaultInjector().decode_fault(nth=nth) as probe:
            eng.step()                  # both admitted; first mixed tick
            at_tick1 = probe.executions
            eng.drain()
        assert eng.health()["occupancy_max"] == 2
        return reng, at_tick1, {c.rid: c.tokens for c in eng.completions}

    # calibration: count fused executions up to the first mixed decode
    # tick on a clean run, then arm the fault just past that point
    _, at_tick1, clean = run("clean", nth=1 << 30)
    reng, _, faulty = run("fault", nth=at_tick1 + 1)
    assert reng.last_rung == "unfused"
    assert FALLBACK_COUNTS["unfused"] >= 1
    for rid in clean:
        np.testing.assert_array_equal(clean[rid], faulty[rid])


# -- sharded serving ---------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (tier1-multidevice CI job)")
def test_scheduler_sharded_parity_8dev():
    """2×4 (data, model) mesh: the scheduler's generate_step traces under
    the mesh — the compressed matmuls take the shard-mapped fused path
    (dispatch probe) — and serving a request next to a co-tenant is
    bitwise-identical to serving it alone through the same pool.  (A
    mesh-less run is NOT the reference: cross-device reduction order
    changes the bf16 floats, so the invariance is asserted *within* the
    mesh, where both runs share one trace.)"""
    from repro.sharding import partition as PT
    cfg = get_config("llama3.2-1b").smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    st = build_serve_params(
        params, CompressionPolicy(mode="compressed", min_weight_size=1024),
        model_shards=4)                    # tiles divide the model axis
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    specs = PT.make_param_specs(st.params, mesh,
                                PT.ShardingConfig(mode="serve"))
    sp = jax.device_put(st.params, PT.to_named(specs, mesh))
    lut = jax.device_put(
        st.lut, jax.NamedSharding(mesh, jax.sharding.PartitionSpec()))
    prompts = _prompts(cfg, 2, seed=17)

    cfgm = dataclasses.replace(cfg, name=cfg.name + "-sched-mesh")
    ctxm = ServeContext(cfg=cfgm, mesh=mesh, lut=lut)
    with mesh, PT.active_mesh(mesh):
        ops.DISPATCH_COUNTS.clear()
        solo = {}
        for i, p in enumerate(prompts):
            eng = Engine(ctxm, sp, n_slots=2, max_len=16)
            eng.submit(Request(tokens=p, max_new=4, rid=i))
            eng.drain()
            solo[i] = eng.completions[0].tokens
        assert any(k.endswith("fused_shard_map")
                   for k in ops.DISPATCH_COUNTS), dict(ops.DISPATCH_COUNTS)
        eng = Engine(ctxm, sp, n_slots=2, max_len=16)
        for i, p in enumerate(prompts):
            eng.submit(Request(tokens=p, max_new=4, rid=i))
        eng.drain()
    both = {c.rid: c.tokens for c in eng.completions}
    for i in range(2):
        np.testing.assert_array_equal(
            solo[i], both[i],
            err_msg=f"request {i} changed under co-tenancy on the mesh")


# -- satellite seams ---------------------------------------------------

def test_impl_enum_is_the_one_home():
    assert ops.Impl("unfused") is ops.Impl.UNFUSED
    assert ops.Impl.UNFUSED.value == "unfused"
    assert str(ops.Impl.UNFUSED) == "unfused"          # f-string safe
    assert f"x+{ops.Impl.MATERIALIZE}" == "x+materialize"
    assert ops.VALID_IMPLS == frozenset(i.value for i in ops.Impl)
    assert ops.DEFAULT_LADDER == ResiliencePolicy().ladder
    prev = ops._DEFAULT_IMPL
    try:
        ops.set_default_impl(ops.Impl.REF)
        assert ops._DEFAULT_IMPL == "ref"
        with pytest.raises(ValueError):
            ops.set_default_impl("warp-speed")
    finally:
        ops.set_default_impl(prev)
    from repro import kernels
    assert kernels.Impl is ops.Impl


def test_serve_context_deprecates_loose_kwargs(served):
    cfg, st, ctx = served
    toks = jnp.asarray(_prompts(cfg, 1, seed=19)[0][None, :])
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        via_ctx = generate(st.params, cfg, toks, ctx=ctx, max_new=3)
    with pytest.warns(DeprecationWarning, match="ServeContext"):
        via_kw = generate(st.params, cfg, toks, lut=st.lut, max_new=3)
    np.testing.assert_array_equal(np.asarray(via_ctx), np.asarray(via_kw))
