"""Fused decode→dequant→matmul megakernel validation.

Pallas kernel (interpret mode) and strip-scan oracle vs the legacy
two-step path, across odd shapes, degenerate dictionaries, and the
row-parallel container; plus the tile-aligned layout invariants and the
ops.dict_decode chunk-padding fix.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import codec, blocked_codec
from repro.core.blocked_codec import build_lut, choose_fused_tiles
from repro.core.compressed import pack_linear, quantize_linear
from repro.kernels import ops, ref
import importlib

fdm_kernel = importlib.import_module("repro.kernels.fused_decode_matmul")


def _packed_pair(rng, n, k, structured=True, table=None):
    """(packed_tiled, packed_linear, lut) for one synthetic weight."""
    if structured:
        w = jnp.asarray(rng.laplace(0.0, 0.02, size=(n, k)).astype(np.float32))
    else:
        w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    ql = quantize_linear(w)
    if table is None:
        table = codec.find_frequent_sequences([np.asarray(ql.values)])
    lut = build_lut(table)
    pt = pack_linear(w, table, lut, tile="auto")
    plin = pack_linear(w, table, lut)
    return pt, plin, jnp.asarray(lut)


# ---------------------------------------------------------------------------
# tile-aligned layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k", [(64, 128), (70, 96), (128, 512), (24, 1000)])
def test_tiled_layout_decodes_bitexact(n, k, rng):
    """Tile-major planes must decode to the same bytes as linear planes."""
    pt, plin, lut = _packed_pair(rng, n, k)
    assert pt.tile_n > 0 and n % pt.tile_n == 0 and k % pt.tile_k == 0
    np.testing.assert_array_equal(np.asarray(pt.materialize_int8(lut)),
                                  np.asarray(plin.materialize_int8(lut)))


def test_choose_fused_tiles_divisors_and_gates():
    tn, tk, bw = choose_fused_tiles((1024, 4096))
    assert (tn, tk) == (128, 512) and bw == 4096
    tn, tk, bw = choose_fused_tiles((70, 96))
    assert 70 % tn == 0 and 96 % tk == 0 and (tn * tk) % bw == 0
    # too small/odd to hold one gram per tile -> no fused layout
    assert choose_fused_tiles((35, 35)) is None


# ---------------------------------------------------------------------------
# fused kernel vs oracle vs two-step, swept over odd shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [
    (8, 64, 128),       # tile-multiple
    (13, 70, 96),       # nothing is a tile multiple
    (1, 128, 512),      # decode-style M=1
    (130, 24, 1000),    # M > bm with remainder, odd N/K
])
def test_fused_matches_oracle_interpret(m, n, k, rng):
    pt, _, lut = _packed_pair(rng, n, k)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    y_ref = ops.decode_dequant_matmul(x, pt, lut, impl="ref",
                                      out_dtype=jnp.float32)
    y_pal = ops.decode_dequant_matmul(x, pt, lut, impl="pallas_interpret",
                                      out_dtype=jnp.float32)
    err = float(jnp.abs(y_pal - y_ref).max() /
                (jnp.abs(y_ref).max() + 1e-9))
    assert err < 2e-2, err  # bf16 MXU x-cast vs f32 oracle


def test_fused_exact_parity_integer_activations(rng):
    """With integer-valued x the bf16 x-cast and every accumulation are
    exact, so the kernel must agree BITWISE with the oracle — the
    acceptance-criterion exactness check for the uint8/affine math.  The
    legacy two-step path materializes w = (q−z)·s (one extra rounding per
    element) so it agrees to f32 roundoff, not bitwise."""
    n, k, m = 64, 256, 16
    pt, _, lut = _packed_pair(rng, n, k)
    x = jnp.asarray(rng.integers(-8, 9, size=(m, k)).astype(np.float32))
    y_oracle = ops.decode_dequant_matmul(x, pt, lut, impl="ref",
                                         out_dtype=jnp.float32)
    y_kernel = ops.decode_dequant_matmul(x, pt, lut, impl="pallas_interpret",
                                         out_dtype=jnp.float32)
    y_twostep = ops.decode_dequant_matmul(x, pt, lut, impl="unfused",
                                          out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y_kernel), np.asarray(y_oracle))
    np.testing.assert_allclose(np.asarray(y_twostep), np.asarray(y_oracle),
                               rtol=1e-4, atol=1e-4)


def test_fused_empty_dictionary(rng):
    """Empty table → every slot escapes; fused decode must still be exact."""
    n, k, m = 32, 128, 8
    pt, _, lut = _packed_pair(rng, n, k, structured=False, table={})
    assert int(np.asarray(pt.nlit).min()) == pt.codes.shape[1]  # all escape
    x = jnp.asarray(rng.integers(-4, 5, size=(m, k)).astype(np.float32))
    y_ref = ops.decode_dequant_matmul(x, pt, lut, impl="ref",
                                      out_dtype=jnp.float32)
    y_pal = ops.decode_dequant_matmul(x, pt, lut, impl="pallas_interpret",
                                      out_dtype=jnp.float32)
    y_two = ops.decode_dequant_matmul(x, pt, lut, impl="unfused",
                                      out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y_pal), np.asarray(y_ref))
    np.testing.assert_allclose(np.asarray(y_two), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_fused_all_escape_blocks_with_nonempty_table(rng):
    """A populated table that never matches this tensor: rank-gather path
    does all the work while the LUT sits unused."""
    n, k, m = 32, 128, 4
    table = {(250, 251, 252, 253): 0}   # gram absent from random bytes
    pt, _, lut = _packed_pair(rng, n, k, structured=False, table=table)
    x = jnp.asarray(rng.integers(-4, 5, size=(m, k)).astype(np.float32))
    y_ref = ops.decode_dequant_matmul(x, pt, lut, impl="ref",
                                      out_dtype=jnp.float32)
    y_pal = ops.decode_dequant_matmul(x, pt, lut, impl="pallas_interpret",
                                      out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y_pal), np.asarray(y_ref))


def test_fused_row_parallel_packed(rng):
    """row_parallel containers take the fused path on a single device and
    stay numerically identical to the plain container."""
    import dataclasses
    n, k, m = 64, 128, 8
    pt, _, lut = _packed_pair(rng, n, k)
    pt_rp = dataclasses.replace(pt, row_parallel=True)
    x = jnp.asarray(rng.integers(-8, 9, size=(m, k)).astype(np.float32))
    y = ops.decode_dequant_matmul(x, pt, lut, impl="pallas_interpret",
                                  out_dtype=jnp.float32)
    y_rp = ops.decode_dequant_matmul(x, pt_rp, lut, impl="pallas_interpret",
                                     out_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_rp))


def test_fused_batched_leading_dims(rng):
    pt, _, lut = _packed_pair(rng, 32, 64)
    x = jnp.asarray(rng.normal(size=(2, 3, 64)).astype(np.float32))
    y = ops.decode_dequant_matmul(x, pt, lut, impl="ref",
                                  out_dtype=jnp.float32)
    assert y.shape == (2, 3, 32)


def test_fused_kernel_grouped_planes_interpret(rng):
    """Column-group axis (the shard-local TiledPackedLinear case): stacked
    per-group tile-major planes through the 4-D grid must agree BITWISE
    with the G=1 kernel over the same dense weight (integer x ⇒ exact)."""
    from repro.core.compressed import pack_linear_tiled
    n, k, m, groups = 64, 256, 16, 4
    w = jnp.asarray(rng.laplace(0.0, 0.02, size=(n, k)).astype(np.float32))
    ql = quantize_linear(w)
    table = codec.find_frequent_sequences([np.asarray(ql.values)])
    lut = build_lut(table)
    pt = pack_linear(w, table, lut, tile="auto")
    tiled = pack_linear_tiled(w, table, lut, tiles=groups, tile="auto")
    assert tiled.codes.ndim == 3 and tiled.tile_n > 0
    x = jnp.asarray(rng.integers(-8, 9, size=(m, k)).astype(np.float32))
    y_grouped = fdm_kernel.fused_decode_matmul(
        x, tiled.codes, tiled.literals, jnp.asarray(lut), tiled.scale,
        tiled.zero, shape=(n, k), tile_n=tiled.tile_n, tile_k=tiled.tile_k,
        interpret=True)
    y_flat = fdm_kernel.fused_decode_matmul(
        x, pt.codes, pt.literals, jnp.asarray(lut), pt.scale, pt.zero,
        shape=(n, k), tile_n=pt.tile_n, tile_k=pt.tile_k, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_grouped), np.asarray(y_flat))


def test_fused_kernel_rejects_nontiled_shapes(rng):
    """Kernel-level API asserts tile alignment (ops handles the padding)."""
    pt, _, lut = _packed_pair(rng, 64, 128)
    x = jnp.ones((4, 96), jnp.float32)
    with pytest.raises(AssertionError):
        fdm_kernel.fused_decode_matmul(
            x, pt.codes, pt.literals, lut, pt.scale, pt.zero,
            shape=(64, 128), tile_n=pt.tile_n, tile_k=pt.tile_k,
            interpret=True)


# ---------------------------------------------------------------------------
# ops.dict_decode chunk padding (prime block counts)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nblocks", [7, 13, 1])
def test_dict_decode_prime_block_counts(nblocks, rng):
    """Prime nb used to shrink the kernel chunk to 1 (one grid step per
    block); now nb pads to a chunk multiple and slices back."""
    n = nblocks * 256
    w = rng.integers(0, 12, size=n).astype(np.uint8)
    table = codec.find_frequent_sequences([w], max_codes=500)
    bc = blocked_codec.encode_blocked(w, table, block_weights=256)
    assert bc.codes.shape[0] == nblocks
    out_ref = ref.dict_decode(bc.codes, bc.literals, bc.nlit, bc.lut)
    out_pal = ops.dict_decode(bc.codes, bc.literals, bc.nlit, bc.lut,
                              impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(out_ref), np.asarray(out_pal))
