"""Grouped expert megakernel — fused decode→dequant→matmul for MoE stacks.

Acceptance contract of the grouped path: compressed expert stacks route
through ``ops.grouped_decode_dequant_matmul`` (probes 'grouped_fused' /
'grouped_fused_shard_map'), dense expert weights never materialize
(``layers.MATERIALIZE_COUNTS['packed_stacked']`` stays zero), and the
numerics match the materialize-dense baseline — across prime expert
counts, capacity-overflow drop slots, shared-expert configs, and 1×1 /
2×4 / 8×1 meshes, in both oracle ('ref') and kernel-body
('pallas_interpret') modes.  Multi-device meshes run in a subprocess
(XLA locks the device count at first init), mirroring
tests/test_sharded_fused.py.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.compressed import PackedLinear, pack_expert_stack
from repro.core.policy import CompressionPolicy
from repro.kernels import ops
from repro.models import layers as L


def _expert_stack(rng, e, n, k, tile=True):
    """Stacked compressed expert weight (shared dictionary, uniform literal
    cap) + lut + the dense f32 stack, as build_serve_params emits it."""
    ws = [rng.laplace(0.0, 0.02, size=(n, k)).astype(np.float32)
          for _ in range(e)]
    packed, lut = pack_expert_stack(ws, tile="auto" if tile else None)
    dense = packed.materialize(lut, jnp.float32)
    return packed, lut, dense


# ---------------------------------------------------------------------------
# op level: kernel vs oracle vs materialized dense, prime expert counts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("e,n,k,m", [
    (3, 64, 128, 8),     # prime E, tile-multiple dims
    (5, 48, 64, 13),     # prime E, odd cap
    (7, 24, 96, 130),    # prime E, cap > DEFAULT_BM with remainder
])
def test_grouped_kernel_bitexact_vs_oracle(e, n, k, m, rng):
    """Integer x ⇒ every accumulation is exact: the grouped Pallas kernel
    must agree BITWISE with the vmapped strip-scan oracle, and to f32
    roundoff with the materialized-dense einsum (which pays one extra
    rounding per element building w = (q−z)·s)."""
    packed, lut, dense = _expert_stack(rng, e, n, k)
    xe = jnp.asarray(rng.integers(-8, 9, size=(e, m, k)).astype(np.float32))
    y_ref = ops.grouped_decode_dequant_matmul(xe, packed, lut, impl="ref",
                                              out_dtype=jnp.float32)
    y_pal = ops.grouped_decode_dequant_matmul(
        xe, packed, lut, impl="pallas_interpret", out_dtype=jnp.float32)
    y_dense = jnp.einsum("emk,enk->emn", xe, dense)
    np.testing.assert_array_equal(np.asarray(y_pal), np.asarray(y_ref))
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-4)


def test_grouped_dispatch_probes_and_fallbacks(rng):
    """Single device: tile-major stacks take 'grouped_fused';
    impl='unfused' and linear-layout stacks fall back to
    'grouped_unfused' (materialize + einsum) with matching numerics."""
    packed, lut, _ = _expert_stack(rng, 4, 32, 128)
    plin, lutl, _ = _expert_stack(rng, 4, 32, 128, tile=False)
    xe = jnp.asarray(rng.normal(size=(4, 8, 128)).astype(np.float32))
    ops.DISPATCH_COUNTS.clear()
    y_f = ops.grouped_decode_dequant_matmul(xe, packed, lut, impl="ref",
                                            out_dtype=jnp.float32)
    y_u = ops.grouped_decode_dequant_matmul(xe, packed, lut, impl="unfused",
                                            out_dtype=jnp.float32)
    assert plin.tile_n == 0
    y_l = ops.grouped_decode_dequant_matmul(xe, plin, lutl, impl="ref",
                                            out_dtype=jnp.float32)
    c = ops.DISPATCH_COUNTS
    assert c["grouped_fused"] == 1 and c["grouped_unfused"] == 2, dict(c)
    err = float(jnp.abs(y_f - y_u).max() / (jnp.abs(y_u).max() + 1e-9))
    # unfused's inner decode/matmul follow the session default impl, which
    # is the bf16 kernel body under REPRO_TEST_IMPL=pallas_interpret
    tol = 1e-4 if ops._DEFAULT_IMPL in ("auto", "ref") else 2e-2
    assert err < tol, err
    assert y_l.shape == y_f.shape


def test_grouped_unfused_default_impl_lever(rng):
    """ops.set_default_impl('unfused') forces the materialize baseline
    through impl='auto' call sites (the benchmark lever)."""
    packed, lut, _ = _expert_stack(rng, 2, 32, 128)
    xe = jnp.asarray(rng.normal(size=(2, 8, 128)).astype(np.float32))
    prev = ops._DEFAULT_IMPL
    try:
        ops.set_default_impl("unfused")
        ops.DISPATCH_COUNTS.clear()
        ops.grouped_decode_dequant_matmul(xe, packed, lut)
        assert ops.DISPATCH_COUNTS["grouped_unfused"] == 1, \
            dict(ops.DISPATCH_COUNTS)
        assert ops.DISPATCH_COUNTS["grouped_fused"] == 0
    finally:
        ops.set_default_impl(prev)


# ---------------------------------------------------------------------------
# layer level: routing/capacity semantics identical across paths
# ---------------------------------------------------------------------------

def _moe_params(rng, cfg):
    """init_moe + build_serve_params → compressed expert stacks."""
    from repro.serve.engine import build_serve_params
    params = L.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    st = build_serve_params(params, CompressionPolicy(mode="compressed",
                                                      min_weight_size=1024))
    wg = st.params["experts"]["w_gate"]
    assert isinstance(wg, PackedLinear) and wg.tile_n > 0 \
        and wg.codes.ndim == 3
    return st


@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
@pytest.mark.parametrize("capacity_factor", [1.25, 0.25])
def test_moe_layer_grouped_matches_materialize(impl, capacity_factor, rng):
    """apply_moe through the grouped kernel == the materialize-dense
    baseline, with and without capacity-overflow drop slots, shared
    experts included.  Identical routing (router is dense either way) —
    only the expert FFN path differs."""
    cfg = dataclasses.replace(get_config("deepseek-v2-lite-16b").smoke,
                              capacity_factor=capacity_factor)
    st = _moe_params(rng, cfg)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)).astype(np.float32))
    ops.DISPATCH_COUNTS.clear()
    L.MATERIALIZE_COUNTS.clear()
    y_f, aux_f = L.apply_moe(st.params, x, cfg, lut=st.lut, impl=impl)
    assert ops.DISPATCH_COUNTS["grouped_fused"] == 3, \
        dict(ops.DISPATCH_COUNTS)
    assert L.MATERIALIZE_COUNTS.get("packed_stacked", 0) == 0, \
        dict(L.MATERIALIZE_COUNTS)
    y_u, aux_u = L.apply_moe(st.params, x, cfg, lut=st.lut, impl="unfused")
    assert ops.DISPATCH_COUNTS["grouped_unfused"] == 3, \
        dict(ops.DISPATCH_COUNTS)
    err = float(jnp.abs(y_f - y_u).max() / (jnp.abs(y_u).max() + 1e-9))
    # strict f32 tolerance only when BOTH paths run f32: the kernel casts
    # x to bf16, and under REPRO_TEST_IMPL=pallas_interpret the unfused
    # baseline's inner dequant_matmul runs the (bf16) kernel body too
    strict = impl == "ref" and ops._DEFAULT_IMPL in ("auto", "ref")
    tol = 1e-4 if strict else 2e-2
    assert err < tol, err
    np.testing.assert_allclose(float(aux_f), float(aux_u), rtol=1e-5)


def test_moe_expert_scan_mode_still_materializes_per_expert(rng):
    """The paper's expert-granular scan mode (single-device edge config)
    keeps its decode-one-expert-at-a-time semantics and matches the
    grouped path."""
    cfg = dataclasses.replace(get_config("deepseek-v2-lite-16b").smoke,
                              moe_expert_scan=True)
    st = _moe_params(rng, cfg)
    x = jnp.asarray(rng.normal(size=(1, 8, cfg.d_model)).astype(np.float32))
    y_s, _ = L.apply_moe(st.params, x, cfg, lut=st.lut, impl="ref")
    cfg2 = dataclasses.replace(cfg, moe_expert_scan=False)
    y_g, _ = L.apply_moe(st.params, x, cfg2, lut=st.lut, impl="ref")
    err = float(jnp.abs(y_s - y_g).max() / (jnp.abs(y_g).max() + 1e-9))
    assert err < 1e-4, err


# ---------------------------------------------------------------------------
# model level: a compressed MoE config serves through the grouped kernel
# ---------------------------------------------------------------------------

def test_moe_generate_zero_expert_materialization(rng):
    """deepseek-v2-lite smoke (MLA + 8 routed + 2 shared experts) under
    ``generate``: every expert matmul dispatches grouped-fused, zero
    materialize calls on expert planes — the PR's acceptance probe."""
    from repro.models import lm as LM
    from repro.serve.engine import build_serve_params, generate

    cfg = get_config("deepseek-v2-lite-16b").smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    st = build_serve_params(params, CompressionPolicy(mode="compressed",
                                                      min_weight_size=1024))
    toks = jnp.ones((2, 8), jnp.int32)
    ops.DISPATCH_COUNTS.clear()
    L.MATERIALIZE_COUNTS.clear()
    out = generate(st.params, cfg, toks, lut=st.lut, max_new=6)
    assert out.shape == (2, 14)
    c = ops.DISPATCH_COUNTS
    assert c["grouped_fused"] > 0, dict(c)
    assert c["grouped_unfused"] == 0, dict(c)
    assert L.MATERIALIZE_COUNTS.get("packed_stacked", 0) == 0, \
        dict(L.MATERIALIZE_COUNTS)
    # numerics: full forward fused vs forced-unfused
    logits_f, _, _ = LM.forward(st.params, cfg, toks, lut=st.lut)
    logits_u, _, _ = LM.forward(st.params, cfg, toks, lut=st.lut,
                                impl="unfused")
    err = float(jnp.abs(logits_f - logits_u).max() /
                (jnp.abs(logits_u).max() + 1e-9))
    assert err < 2e-2, err


# ---------------------------------------------------------------------------
# meshes: 1×1 / 2×4 / 8×1 expert-parallel parity (subprocess: XLA locks the
# device count at first init)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.core.policy import CompressionPolicy
from repro.kernels import ops
from repro.models import layers as L
from repro.models import lm as LM
from repro.serve.engine import build_serve_params
from repro.sharding import partition as PT

cfg = get_config("deepseek-v2-lite-16b").smoke
params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
st = build_serve_params(params, CompressionPolicy(mode="compressed",
                                                  min_weight_size=1024),
                        model_shards=4)
toks = jnp.ones((2, 8), jnp.int32)

def prefill_logits(cfg_v, mesh, impl):
    caches = LM.init_caches(cfg_v, 2, 14, dtype=jnp.float32)
    specs = PT.make_param_specs(st.params, mesh,
                                PT.ShardingConfig(mode="serve"))
    sp = jax.device_put(st.params, PT.to_named(specs, mesh))
    lut = jax.device_put(st.lut, jax.NamedSharding(
        mesh, jax.sharding.PartitionSpec()))
    @jax.jit
    def f(sp, lut, toks, caches):
        with PT.active_mesh(mesh):
            logits, _, _ = LM.forward(sp, cfg_v, toks, caches=caches,
                                      pos=0, lut=lut, impl=impl)
        return logits[:, -1]
    with mesh:
        return f(sp, lut, toks, caches)

def relerr(a, b):
    return float(jnp.abs(a - b).max() / (jnp.abs(b).max() + 1e-9))

# expert-parallel dispatch: 8 experts over the model axis when it divides;
# graceful materialize fallback on the data-only mesh
for shape, want in (((1, 1), "grouped_fused"),
                    ((2, 4), "grouped_fused_shard_map"),
                    ((8, 1), "grouped_unfused")):
    mesh = jax.make_mesh(shape, ("data", "model"))
    ops.DISPATCH_COUNTS.clear()
    L.MATERIALIZE_COUNTS.clear()
    lf = prefill_logits(cfg, mesh, "auto")
    c = dict(ops.DISPATCH_COUNTS)
    assert c.get(want, 0) > 0, (shape, c)
    if want != "grouped_unfused":
        assert c.get("grouped_unfused", 0) == 0, (shape, c)
        assert L.MATERIALIZE_COUNTS.get("packed_stacked", 0) == 0, \
            (shape, dict(L.MATERIALIZE_COUNTS))
    lu = prefill_logits(cfg, mesh, "unfused")
    e = relerr(lf, lu)
    assert e < 2e-2, (shape, e)

# local-routing MoE (shard_map dispatch) on the 2x4 mesh: compressed
# planes enter the shard_map expert-sharded, grouped kernel runs per shard
cfg_l = dataclasses.replace(cfg, moe_local_dispatch=True,
                            name=cfg.name + "-local")
mesh = jax.make_mesh((2, 4), ("data", "model"))
ops.DISPATCH_COUNTS.clear()
L.MATERIALIZE_COUNTS.clear()
lf = prefill_logits(cfg_l, mesh, "auto")
c = dict(ops.DISPATCH_COUNTS)
assert c.get("grouped_fused_shard_map", 0) > 0, c
assert L.MATERIALIZE_COUNTS.get("packed_stacked", 0) == 0, \
    dict(L.MATERIALIZE_COUNTS)
lu = prefill_logits(cfg_l, mesh, "unfused")
assert relerr(lf, lu) < 2e-2, relerr(lf, lu)

print("MOE_MESH_OK")
"""


@pytest.mark.slow
def test_moe_mesh_parity_subprocess():
    """1×1 / 2×4 / 8×1 meshes: grouped dispatch probes + fused-vs-unfused
    parity for the global and local-routing MoE paths.  REPRO_TEST_IMPL
    passes through, so the kernel-interpret CI job runs the grouped
    kernel *body* under the shard-local (E/msize) shapes too."""
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    if os.environ.get("REPRO_TEST_IMPL"):
        env["REPRO_TEST_IMPL"] = os.environ["REPRO_TEST_IMPL"]
    r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                       capture_output=True, text=True, timeout=1800,
                       env=env)
    assert "MOE_MESH_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (tier1-multidevice CI job)")
def test_moe_generate_grouped_shard_map_8dev(rng):
    """Multi-device CI acceptance: one MoE-config generate through the
    grouped shard-mapped expert path, dispatch-probe asserted."""
    from repro.models import lm as LM
    from repro.serve.engine import build_serve_params, generate
    from repro.sharding import partition as PT

    cfg = dataclasses.replace(get_config("deepseek-v2-lite-16b").smoke,
                              moe_local_dispatch=True,
                              name="deepseek-v2-lite-smoke-local8")
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    st = build_serve_params(params, CompressionPolicy(mode="compressed",
                                                      min_weight_size=1024),
                            model_shards=4)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    specs = PT.make_param_specs(st.params, mesh,
                                PT.ShardingConfig(mode="serve"))
    sp = jax.device_put(st.params, PT.to_named(specs, mesh))
    lut = jax.device_put(st.lut, jax.NamedSharding(
        mesh, jax.sharding.PartitionSpec()))
    toks = jnp.ones((2, 8), jnp.int32)
    ops.DISPATCH_COUNTS.clear()
    L.MATERIALIZE_COUNTS.clear()
    out = generate(sp, cfg, toks, lut=lut, max_new=6, mesh=mesh)
    assert out.shape == (2, 14)
    c = ops.DISPATCH_COUNTS
    assert c["grouped_fused_shard_map"] > 0, dict(c)
    assert c.get("grouped_unfused", 0) == 0, dict(c)
    assert L.MATERIALIZE_COUNTS.get("packed_stacked", 0) == 0, \
        dict(L.MATERIALIZE_COUNTS)
