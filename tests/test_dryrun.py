"""Dry-run machinery tests — the core distribution deliverable.

The full 512-device sweep lives in launch/dryrun.py (results committed in
EXPERIMENTS.md); here a subprocess compiles ONE real cell end-to-end as a
regression guard, plus unit tests for the trip-weighted HLO cost model.
"""
import subprocess
import sys

import pytest

from repro.launch import hlo_stats


_CELL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
rec = run_cell("internlm2-1.8b", "decode_32k", multi_pod=False)
assert rec["ok"], rec.get("error")
assert rec["memory"]["total_hbm_bytes"] > 0
assert rec["hlo_cost"]["flops"] > 0
assert rec["collectives"]["total_bytes"] >= 0
# the decode collective fix (§Perf D1/6) must hold: < 2 GiB per step
assert rec["collectives"]["total_bytes"] < 2 * 2**30, \
    rec["collectives"]["total_bytes"]
# fits the 16 GiB v5e HBM
assert rec["memory"]["total_hbm_bytes"] < 16 * 2**30
print("CELL_OK")
"""


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    r = subprocess.run([sys.executable, "-c", _CELL_SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "CELL_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


_HLO = """
HloModule m

%fused_computation.1 (param_0.1: f32[8,64], param_1.1: s32[]) -> f32[1,64] {
  %param_0.1 = f32[8,64]{1,0} parameter(0)
  %param_1.1 = s32[] parameter(1)
  ROOT %ds = f32[1,64]{1,0} dynamic-slice(%param_0.1, %param_1.1), dynamic_slice_sizes={1,64}
}

%body (p: (s32[], f32[4,8], f32[8,64])) -> (s32[], f32[4,8], f32[8,64]) {
  %p = (s32[], f32[4,8], f32[8,64]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %a = f32[4,8]{1,0} get-tuple-element(%p), index=1
  %big = f32[8,64]{1,0} get-tuple-element(%p), index=2
  %sl = f32[1,64]{1,0} fusion(%big, %iv), kind=kLoop, calls=%fused_computation.1
  %b = f32[8,4]{1,0} transpose(%a), dimensions={1,0}
  %dot = f32[4,4]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[4,8], f32[8,64]) tuple(%iv, %a, %big)
}

%cond (p: (s32[], f32[4,8], f32[8,64])) -> pred[] {
  %p = (s32[], f32[4,8], f32[8,64]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%iv, %c), direction=LT
}

ENTRY %main (x: f32[4,8]) -> f32[4,8] {
  %x = f32[4,8]{1,0} parameter(0)
  %w = (s32[], f32[4,8], f32[8,64]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[4,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_cost_trip_weighted_dot_flops():
    cost = hlo_stats.hlo_cost(_HLO)
    # dot: 2 * (4*4 result) * 8 contracting = 256 flops, * 10 trips
    assert cost["flops"] == 256 * 10, cost


def test_hlo_cost_fusion_slice_reads():
    # the fusion reads a (1,64) slice of the (8,64) param, not all of it
    comps = hlo_stats._split_computations(_HLO)
    assert "fused_computation.1" in comps
    fusion_ln = next(ln for ln in comps["body"] if " fusion(" in ln)
    reads = hlo_stats._fusion_read_bytes(fusion_ln, [8 * 64 * 4, 4], comps)
    assert reads == 1 * 64 * 4 + 4, reads   # 256 B slice + 4 B index, not 2052
    # and the full walk stays far below the naive all-operand count
    cost = hlo_stats.hlo_cost(_HLO)
    assert 10_000 <= cost["bytes"] <= 16_000, cost


def test_computation_weights_nested():
    comps = hlo_stats._split_computations(_HLO)
    trips = hlo_stats._find_while_trips(comps)
    w = hlo_stats._computation_weights(comps, trips)
    assert w["body"] == 10
    assert w["main"] == 1
    # fusion computations are costed at the call site, not walked
    assert w.get("fused_computation.1", 0) == 0
