"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes as required by the deliverables."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import codec, blocked_codec
from repro.kernels import ops, ref
# package __init__ re-exports the ops wrappers under the same names as the
# kernel modules (shadowing the module attributes) — use importlib
import importlib
dqmm_kernel = importlib.import_module("repro.kernels.dequant_matmul")
dd_kernel = importlib.import_module("repro.kernels.dict_decode")
fa_kernel = importlib.import_module("repro.kernels.flash_attention")


# ---------------------------------------------------------------------------
# dequant_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [(8, 16, 32), (128, 128, 512),
                                   (64, 256, 128), (130, 70, 96),
                                   (1, 128, 256)])
@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_dequant_matmul_matches_ref(m, n, k, xdtype, rng):
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32)).astype(xdtype)
    wq = jnp.asarray(rng.integers(0, 256, size=(n, k)).astype(np.uint8))
    scale = jnp.asarray(rng.uniform(0.01, 0.1, size=(n, 1)).astype(np.float32))
    zero = jnp.asarray(rng.integers(100, 156, size=(n, 1)).astype(np.float32))
    y_ref = ops.dequant_matmul(x, wq, scale, zero, impl="ref")
    y_pal = ops.dequant_matmul(x, wq, scale, zero, impl="pallas_interpret")
    scale_mag = float(jnp.abs(y_ref).max()) + 1e-6
    # kernel computes the matmul in bf16 (exact for uint8 codes, lossy for x)
    tol = 2e-2 if xdtype == jnp.bfloat16 else 5e-3
    assert float(jnp.abs(y_ref - y_pal).max()) / scale_mag < tol


def test_dequant_matmul_affine_identity(rng):
    """Kernel epilogue math: y == x @ ((q - z)·s).T exactly (f32 ref)."""
    x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    wq = jnp.asarray(rng.integers(0, 256, size=(32, 64)).astype(np.uint8))
    scale = jnp.asarray(rng.uniform(0.01, 1.0, size=(32, 1)).astype(np.float32))
    zero = jnp.asarray(rng.integers(0, 255, size=(32, 1)).astype(np.float32))
    w = (wq.astype(jnp.float32) - zero) * scale
    expect = x @ w.T
    got = ops.dequant_matmul(x, wq, scale, zero, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_dequant_matmul_batched_leading_dims(rng):
    x = jnp.asarray(rng.normal(size=(2, 3, 32)).astype(np.float32))
    wq = jnp.asarray(rng.integers(0, 256, size=(16, 32)).astype(np.uint8))
    scale = jnp.ones((16, 1), jnp.float32) * 0.1
    zero = jnp.zeros((16, 1), jnp.float32)
    y = ops.dequant_matmul(x, wq, scale, zero, impl="ref")
    assert y.shape == (2, 3, 16)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 16), (16, 32, 32)])
def test_dequant_matmul_block_shapes(bm, bn, bk, rng):
    x = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32))
    wq = jnp.asarray(rng.integers(0, 256, size=(64, 64)).astype(np.uint8))
    scale = jnp.full((64, 1), 0.05, jnp.float32)
    zero = jnp.full((64, 1), 127.0, jnp.float32)
    y_ref = ops.dequant_matmul(x, wq, scale, zero, impl="ref")
    y_pal = ops.dequant_matmul(x, wq, scale, zero, impl="pallas_interpret",
                               bm=bm, bn=bn, bk=bk)
    err = float(jnp.abs(y_pal - y_ref).max() / (jnp.abs(y_ref).max() + 1e-9))
    assert err < 1e-2, err  # bf16 MXU accumulation vs f32 ref


# ---------------------------------------------------------------------------
# dict_decode
# ---------------------------------------------------------------------------

def _encoded(rng, n, block_weights=1024, alphabet=12):
    pats = rng.integers(0, alphabet, size=(16, 8)).astype(np.uint8)
    picks = rng.integers(0, 16, size=n // 8 + 1)
    w = np.concatenate([pats[p] for p in picks])[:n]
    table = codec.find_frequent_sequences([w], max_codes=2000)
    return w, blocked_codec.encode_blocked(w, table,
                                           block_weights=block_weights)


@pytest.mark.parametrize("n,bw", [(4096, 1024), (16 * 1024, 4096),
                                  (2048, 256), (8192, 512)])
def test_dict_decode_bitexact(n, bw, rng):
    w, bc = _encoded(rng, n, bw)
    out_ref = ref.dict_decode(bc.codes, bc.literals, bc.nlit, bc.lut)
    out_pal = ops.dict_decode(bc.codes, bc.literals, bc.nlit, bc.lut,
                              impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(out_ref), np.asarray(out_pal))
    np.testing.assert_array_equal(np.asarray(out_pal).reshape(-1)[:n], w)


def test_dict_decode_all_escape(rng):
    """Empty dictionary → every slot escapes; decode must still be exact."""
    w = rng.integers(0, 256, size=2048).astype(np.uint8)
    bc = blocked_codec.encode_blocked(w, {}, block_weights=512)
    out = ops.dict_decode(bc.codes, bc.literals, bc.nlit, bc.lut,
                          impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(out).reshape(-1)[:2048], w)


def test_dict_decode_all_hits(rng):
    """Single repeated gram → no escapes, pure LUT path."""
    w = np.tile(np.array([7, 3, 1, 9], np.uint8), 1024)
    table = codec.find_frequent_sequences([w])
    bc = blocked_codec.encode_blocked(w, table, block_weights=1024)
    assert int(np.asarray(bc.nlit).sum()) == 0
    out = ops.dict_decode(bc.codes, bc.literals, bc.nlit, bc.lut,
                          impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(out).reshape(-1)[:w.size], w)


@pytest.mark.parametrize("chunk", [1, 2, 8])
def test_dict_decode_chunking(chunk, rng):
    w, bc = _encoded(rng, 8192, 512)
    out = dd_kernel.dict_decode(bc.codes, bc.literals, bc.nlit, bc.lut,
                                chunk=chunk, interpret=True)
    np.testing.assert_array_equal(np.asarray(out).reshape(-1)[:8192], w)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,tq,tk,d", [
    (1, 4, 4, 128, 128, 32),      # MHA
    (2, 8, 2, 256, 256, 64),      # GQA 4x
    (1, 4, 1, 128, 512, 32),      # MQA, tk > tq
    (2, 4, 4, 64, 256, 16),       # decode-ish: small tq big tk
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_vs_naive(b, hq, hkv, tq, tk, d, causal, rng):
    q = jnp.asarray(rng.normal(size=(b, hq, tq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, hkv, tk, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, hkv, tk, d)).astype(np.float32))
    off = tk - tq if causal else 0
    o_naive = ref.attention_naive(q, k, v, causal=causal, q_offset=off)
    o_pal = fa_kernel.flash_attention(q, k, v, causal=causal, q_offset=off,
                                      bq=64, bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_naive),
                               rtol=2e-4, atol=2e-4)


def test_flash_ref_chunked_matches_naive(rng):
    """jnp-flash (the CPU/serving path) against the naive oracle."""
    q = jnp.asarray(rng.normal(size=(2, 4, 256, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 2, 256, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 2, 256, 32)).astype(np.float32))
    o_chunk = ref.flash_attention(q, k, v, causal=True, kv_chunk=64)
    o_naive = ref.attention_naive(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o_chunk), np.asarray(o_naive),
                               rtol=2e-4, atol=2e-4)


def test_flash_q_offset_decode_semantics(rng):
    """Decode: 1 query at position L-1 must equal full-attention row L-1."""
    b, h, L, d = 1, 2, 128, 16
    q_full = jnp.asarray(rng.normal(size=(b, h, L, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, h, L, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, h, L, d)).astype(np.float32))
    o_full = ref.attention_naive(q_full, k, v, causal=True)
    o_last = fa_kernel.flash_attention(
        q_full[:, :, -1:, :], k, v, causal=True, q_offset=L - 1,
        bq=1, bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o_last)[:, :, 0],
                               np.asarray(o_full)[:, :, -1],
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype, rng):
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 32)).astype(np.float32)).astype(dtype)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 32)).astype(np.float32)).astype(dtype)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 32)).astype(np.float32)).astype(dtype)
    o_pal = fa_kernel.flash_attention(q, k, v, causal=True, bq=64, bk=64,
                                      interpret=True)
    o_ref = ref.attention_naive(q, k, v, causal=True)
    assert o_pal.dtype == dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(o_pal, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_softmax_rows_normalized(rng):
    """Property: output is a convex combination of V rows (causal row 0
    attends only position 0)."""
    q = jnp.asarray(rng.normal(size=(1, 1, 64, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 64, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 1, 64, 16)).astype(np.float32))
    o = fa_kernel.flash_attention(q, k, v, causal=True, bq=32, bk=32,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(o)[0, 0, 0], np.asarray(v)[0, 0, 0],
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fused decode → dequant → matmul (the paper's serving hot path)
# ---------------------------------------------------------------------------

def test_decode_dequant_matmul_end_to_end(rng):
    from repro.core.compressed import pack_linear
    from repro.core.blocked_codec import build_lut
    w = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32))
    from repro.core.compressed import quantize_linear
    ql = quantize_linear(w)
    table = codec.find_frequent_sequences([np.asarray(ql.values)])
    lut = build_lut(table)
    packed = pack_linear(w, table, lut, block_weights=1024)
    x = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
    y_fused = ops.decode_dequant_matmul(x, packed, jnp.asarray(lut),
                                        impl="ref", out_dtype=jnp.float32)
    w_deq = (ql.values.astype(jnp.float32) - ql.zero) * ql.scale
    y_expect = x @ w_deq.T
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_expect),
                               rtol=1e-4, atol=1e-4)


def test_decode_dequant_matmul_pallas_interpret(rng):
    from repro.core.compressed import pack_linear, quantize_linear
    from repro.core.blocked_codec import build_lut
    w = jnp.asarray(rng.normal(size=(32, 256)).astype(np.float32))
    ql = quantize_linear(w)
    table = codec.find_frequent_sequences([np.asarray(ql.values)])
    lut = build_lut(table)
    packed = pack_linear(w, table, lut, block_weights=512)
    x = jnp.asarray(rng.normal(size=(16, 256)).astype(np.float32))
    y_ref = ops.decode_dequant_matmul(x, packed, jnp.asarray(lut), impl="ref")
    y_pal = ops.decode_dequant_matmul(x, packed, jnp.asarray(lut),
                                      impl="pallas_interpret")
    err = float(jnp.abs(y_pal.astype(jnp.float32) -
                        y_ref.astype(jnp.float32)).max() /
                (jnp.abs(y_ref.astype(jnp.float32)).max() + 1e-9))
    assert err < 2e-2, err  # bf16 MXU accumulation vs f32 ref
