"""Paper §5 latency discussion — decompression overhead on CPU.

The paper's own latency numbers are CPU-measured (Xeon 6130): dense vs
quantized vs compressed per-example latency, where compressed pays the
layer-by-layer decode cost.  This container is also CPU, so these are real
wall-clock measurements of the same pipeline (smoke-scale model).

Also measures the microbench the serving engine cares about: dict_decode +
dequant_matmul throughput vs a dense matmul of the same shape.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import codec
from repro.core.blocked_codec import build_lut
from repro.core.compressed import pack_linear, quantize_linear
from repro.core.policy import CompressionPolicy
from repro.kernels import ops
from repro.serve.engine import build_serve_params, generate

from .common import emit, time_call, trained_tiny_model


def serving_latency():
    cfg, params, _ = trained_tiny_model(steps=60)
    toks = jnp.ones((4, 16), jnp.int32)

    modes = {"dense": (params, None)}
    for mode in ("quant", "compressed"):
        st = build_serve_params(params, CompressionPolicy(
            mode=mode, min_weight_size=1024))
        modes[mode] = (st.params, st.lut)

    for mode, (p, lut) in modes.items():
        t = time_call(lambda p=p, lut=lut: generate(p, cfg, toks, lut=lut,
                                                    max_new=8),
                      warmup=1, iters=3)
        emit(f"latency.generate8.{mode}_s", f"{t:.4f}",
             "batch=4 prompt=16 (paper: compressed ~1.5-5x dense on CPU)")


def kernel_latency():
    rng = np.random.default_rng(0)
    n, k, m = 1024, 1024, 256
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32) * 0.02)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    ql = quantize_linear(w)
    table = codec.find_frequent_sequences([np.asarray(ql.values)])
    lut = jnp.asarray(build_lut(table))
    packed = pack_linear(w, table, np.asarray(lut))

    dense = jax.jit(lambda x: x @ w.T)
    quant = jax.jit(lambda x: ops.dequant_matmul(x, ql.values, ql.scale,
                                                 ql.zero, impl="ref"))
    comp = jax.jit(lambda x: ops.decode_dequant_matmul(x, packed, lut,
                                                       impl="ref"))
    td = time_call(dense, x)
    tq = time_call(quant, x)
    tc = time_call(comp, x)
    emit("latency.matmul_1024x1024.dense_us", f"{td*1e6:.1f}", "")
    emit("latency.matmul_1024x1024.quant_us", f"{tq*1e6:.1f}",
         f"{tq/td:.2f}x dense")
    emit("latency.matmul_1024x1024.compressed_us", f"{tc*1e6:.1f}",
         f"{tc/td:.2f}x dense (decode amortized per call)")


def main():
    serving_latency()
    kernel_latency()


if __name__ == "__main__":
    main()
