"""Paper §5 latency discussion — decompression overhead on CPU.

The paper's own latency numbers are CPU-measured (Xeon 6130): dense vs
quantized vs compressed per-example latency, where compressed pays the
layer-by-layer decode cost.  This container is also CPU, so these are real
wall-clock measurements of the same pipeline (smoke-scale model).

Also measures the microbenches the serving engine cares about:
  * kernel_latency — dict_decode + dequant_matmul vs a dense matmul.
  * fused_latency  — the fused decode→dequant→matmul path vs the legacy
    two-step (``impl='unfused'``) path at 1024² and 4096², with an
    estimated bytes-moved model alongside wall clock: the fused kernel
    replaces the 2·N·K dense-weight HBM round-trip with the compressed
    payload streamed per M-tile, which is the whole point of the
    megakernel (see kernels/fused_decode_matmul.py).
"""
from __future__ import annotations

import json

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import codec
from repro.core.blocked_codec import build_lut, choose_fused_tiles
from repro.core.compressed import (pack_expert_stack, pack_linear,
                                   quantize_linear)
from repro.core.policy import CompressionPolicy
from repro.kernels import ops
from repro.kernels.fused_decode_matmul import DEFAULT_BM
from repro.serve.context import ServeContext
from repro.serve.engine import build_serve_params, generate

from .common import emit, time_call, trained_tiny_model, \
    synthetic_trained_weights


def serving_latency():
    cfg, params, _ = trained_tiny_model(steps=60)
    toks = jnp.ones((4, 16), jnp.int32)

    modes = {"dense": (params, None)}
    for mode in ("quant", "compressed"):
        st = build_serve_params(params, CompressionPolicy(
            mode=mode, min_weight_size=1024))
        modes[mode] = (st.params, st.lut)

    for mode, (p, lut) in modes.items():
        ctx = ServeContext(cfg=cfg, lut=lut)
        t = time_call(lambda p=p, ctx=ctx: generate(p, cfg, toks, ctx=ctx,
                                                    max_new=8),
                      warmup=1, iters=3)
        emit(f"latency.generate8.{mode}_s", f"{t:.4f}",
             "batch=4 prompt=16 (paper: compressed ~1.5-5x dense on CPU)")


def kernel_latency():
    rng = np.random.default_rng(0)
    n, k, m = 1024, 1024, 256
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32) * 0.02)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    ql = quantize_linear(w)
    table = codec.find_frequent_sequences([np.asarray(ql.values)])
    lut = jnp.asarray(build_lut(table))
    packed = pack_linear(w, table, np.asarray(lut), tile="auto")

    dense = jax.jit(lambda x: x @ w.T)
    quant = jax.jit(lambda x: ops.dequant_matmul(x, ql.values, ql.scale,
                                                 ql.zero, impl="ref"))
    comp = jax.jit(lambda x: ops.decode_dequant_matmul(x, packed, lut,
                                                       impl="ref"))
    td = time_call(dense, x)
    tq = time_call(quant, x)
    tc = time_call(comp, x)
    emit("latency.matmul_1024x1024.dense_us", f"{td*1e6:.1f}", "")
    emit("latency.matmul_1024x1024.quant_us", f"{tq*1e6:.1f}",
         f"{tq/td:.2f}x dense")
    emit("latency.matmul_1024x1024.compressed_us", f"{tc*1e6:.1f}",
         f"{tc/td:.2f}x dense (decode amortized per call)")


def _fused_bytes_model(m, n, k, payload, bm=DEFAULT_BM, tile_n=128,
                       dtype_bytes=4):
    """Estimated HBM bytes moved per call (TPU kernel traffic model).

    unfused: compressed payload in, dense uint8 weight written to HBM by
    dict_decode and read back by dequant_matmul (the 2·N·K round-trip),
    plus activations/outputs.
    fused:   compressed payload re-streamed once per M-tile of the grid,
    output written once; the decoded weight never leaves VMEM.
    Both matmul grids re-stream x once per N-tile (same 128-wide tiles),
    so that term is common and the delta is purely the weight traffic:
    2·N·K dense round-trip vs (M/bm)·payload.  Returns
    (unfused_total, fused_total, unfused_weight, fused_weight) so callers
    can report the weight-traffic ratio undiluted by the shared x/y terms.
    """
    x_b = -(-n // tile_n) * m * k * dtype_bytes    # per-N-tile x re-stream
    y_b = m * n * dtype_bytes
    w_unfused = payload + 2 * n * k
    w_fused = -(-m // bm) * payload
    return w_unfused + x_b + y_b, w_fused + x_b + y_b, w_unfused, w_fused


def fused_latency(rows: list | None = None):
    """Single-device fused vs unfused.  Appends machine-readable rows to
    ``rows`` (the BENCH_latency.json payload) alongside the CSV emits."""
    rng = np.random.default_rng(0)
    m = 256
    for size in (1024, 4096):
        n = k = size
        w = jnp.asarray(synthetic_trained_weights(rng, (n, k)))
        ql = quantize_linear(w)
        table = codec.find_frequent_sequences([np.asarray(ql.values)])
        lut = jnp.asarray(build_lut(table))
        packed = pack_linear(w, table, np.asarray(lut), tile="auto")
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        # packed is an argument (not a closure constant) so XLA doesn't
        # constant-fold the decode into the compile.
        fused = jax.jit(lambda x, p: ops.decode_dequant_matmul(
            x, p, lut, out_dtype=jnp.float32))
        unfused = jax.jit(lambda x, p: ops.decode_dequant_matmul(
            x, p, lut, impl="unfused", out_dtype=jnp.float32))
        tf = time_call(fused, x, packed, iters=10)
        tu = time_call(unfused, x, packed, iters=10)
        ub, fb, uw, fw = _fused_bytes_model(m, n, k, packed.payload_nbytes,
                                            tile_n=packed.tile_n or 128)
        tag = f"latency.fused_matmul_{size}x{size}"
        emit(f"{tag}.unfused_ms", f"{tu*1e3:.2f}",
             f"two-step decode→matmul, ~{ub/2**20:.1f} MiB moved "
             f"({uw/2**20:.1f} MiB weight)")
        emit(f"{tag}.fused_ms", f"{tf*1e3:.2f}",
             f"{tu/tf:.2f}x unfused, ~{fb/2**20:.1f} MiB moved "
             f"({fw/2**20:.1f} MiB weight, {uw/fw:.1f}x fewer weight bytes)")
        if rows is not None:
            common = dict(bench="fused_matmul", m=m, n=n, k=k, devices=1,
                          mesh=None)
            rows.append(dict(common, path="unfused", wall_ms=tu * 1e3,
                             est_bytes_moved=ub, est_weight_bytes=uw))
            rows.append(dict(common, path="fused", wall_ms=tf * 1e3,
                             est_bytes_moved=fb, est_weight_bytes=fw,
                             speedup_vs_unfused=tu / tf))


def sharded_fused_latency(rows: list | None = None):
    """Shard-mapped fused vs unfused on a (data, model) mesh over the host
    devices.  Needs >1 device (CI exports
    XLA_FLAGS=--xla_force_host_platform_device_count=8); on a single
    device it emits a skip marker so the JSON schema stays stable."""
    from repro.sharding import partition as PT

    ndev = jax.device_count()
    if ndev < 2:
        emit("latency.sharded_fused.skipped", "1", "single device")
        if rows is not None:
            rows.append(dict(bench="fused_matmul", devices=ndev, mesh=None,
                             path="fused_shard_map", skipped="single device"))
        return
    msize = min(4, ndev)
    dsize = ndev // msize
    mesh = jax.make_mesh((dsize, msize), ("data", "model"))
    rng = np.random.default_rng(0)
    m, size = 256, 1024
    n = k = size
    w = jnp.asarray(synthetic_trained_weights(rng, (n, k)))
    ql = quantize_linear(w)
    table = codec.find_frequent_sequences([np.asarray(ql.values)])
    lut = jnp.asarray(build_lut(table))
    picked = choose_fused_tiles((n, k), shards=(msize, 1))
    packed = pack_linear(w, table, np.asarray(lut), tile=picked[:2])
    if (n // packed.tile_n) % msize != 0:
        # odd device counts (3, 5, ...) where the out-tile bands cannot
        # split over the model axis: record the skip, don't crash the
        # JSON artifact
        emit("latency.sharded_fused.skipped", "1",
             f"out-tiles !% model={msize}")
        if rows is not None:
            rows.append(dict(bench="fused_matmul", devices=ndev,
                             mesh=[dsize, msize], path="fused_shard_map",
                             skipped=f"out-tiles !% model={msize}"))
        return
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    with mesh, PT.active_mesh(mesh):
        fused = jax.jit(lambda x, p: ops.decode_dequant_matmul(
            x, p, lut, out_dtype=jnp.float32))
        unfused = jax.jit(lambda x, p: ops.decode_dequant_matmul(
            x, p, lut, impl="unfused", out_dtype=jnp.float32))
        ops.DISPATCH_COUNTS.clear()
        tf = time_call(fused, x, packed, iters=10)
        tu = time_call(unfused, x, packed, iters=10)
        assert ops.DISPATCH_COUNTS["fused_shard_map"] >= 1, \
            dict(ops.DISPATCH_COUNTS)
    tag = f"latency.sharded_fused_matmul_{size}x{size}.mesh{dsize}x{msize}"
    emit(f"{tag}.unfused_ms", f"{tu*1e3:.2f}", "two-step under mesh")
    emit(f"{tag}.fused_ms", f"{tf*1e3:.2f}",
         f"{tu/tf:.2f}x unfused, shard-mapped megakernel")
    if rows is not None:
        common = dict(bench="fused_matmul", m=m, n=n, k=k, devices=ndev,
                      mesh=[dsize, msize])
        rows.append(dict(common, path="unfused", wall_ms=tu * 1e3))
        rows.append(dict(common, path="fused_shard_map", wall_ms=tf * 1e3,
                         speedup_vs_unfused=tu / tf))


def _moe_expert_stack(rng, e, n, k):
    """Synthetic stacked compressed expert weight (one shared dictionary,
    tile-major planes, uniform literal cap) — what build_serve_params
    emits for ``experts/w_*`` leaves."""
    ws = [synthetic_trained_weights(rng, (n, k)) for _ in range(e)]
    return pack_expert_stack(ws)


def moe_fused_latency(rows: list | None = None):
    """Grouped expert megakernel vs the materialize-dense baseline.

    One stacked expert matmul (E, cap, d) × compressed (E, n, d) planes —
    the MoE serving hot loop.  The unfused baseline decodes the whole
    dense expert stack to HBM (E·n·d uint8 written + read back) before the
    einsum; the grouped kernel streams the compressed blocks per
    (expert, tile) instead.  tokens/s counts the E·cap gathered token
    slots each call processes.
    """
    rng = np.random.default_rng(0)
    # cap = one M-tile (decode-style capacity): the grouped grid streams
    # the compressed payload exactly once, the baseline still pays the
    # full dense round-trip
    e, n, k, cap = 4, 2048, 2048, 128
    packed, lut = _moe_expert_stack(rng, e, n, k)
    xe = jnp.asarray(rng.normal(size=(e, cap, k)).astype(np.float32))
    grouped = jax.jit(lambda x, p: ops.grouped_decode_dequant_matmul(
        x, p, lut, out_dtype=jnp.float32))
    unfused = jax.jit(lambda x, p: ops.grouped_decode_dequant_matmul(
        x, p, lut, impl="unfused", out_dtype=jnp.float32))
    ops.DISPATCH_COUNTS.clear()
    tg = time_call(grouped, xe, packed, iters=10)
    tu = time_call(unfused, xe, packed, iters=10)
    assert ops.DISPATCH_COUNTS["grouped_fused"] >= 1, \
        dict(ops.DISPATCH_COUNTS)
    tokens = e * cap
    # weight-byte traffic: the baseline's 2·E·n·k dense round-trip vs the
    # compressed payload re-streamed once per M-tile of the grid
    uw = packed.payload_nbytes + 2 * e * n * k
    fw = -(-cap // DEFAULT_BM) * packed.payload_nbytes
    tag = f"latency.moe_grouped_{e}x{n}x{k}"
    emit(f"{tag}.unfused_ms", f"{tu*1e3:.2f}",
         f"materialize-dense experts, ~{uw/2**20:.1f} MiB weight traffic")
    emit(f"{tag}.grouped_ms", f"{tg*1e3:.2f}",
         f"{tu/tg:.2f}x unfused, ~{fw/2**20:.1f} MiB weight "
         f"({uw/fw:.1f}x fewer weight bytes)")
    if rows is not None:
        common = dict(bench="moe_grouped_matmul", experts=e, n=n, k=k,
                      cap=cap, devices=1, mesh=None)
        rows.append(dict(common, path="unfused", wall_ms=tu * 1e3,
                         tokens_per_s=tokens / tu, est_weight_bytes=uw))
        rows.append(dict(common, path="grouped_fused", wall_ms=tg * 1e3,
                         tokens_per_s=tokens / tg, est_weight_bytes=fw,
                         speedup_vs_unfused=tu / tg))


def moe_generate_latency(rows: list | None = None):
    """End-to-end MoE serving: deepseek-v2-lite smoke ``generate`` with the
    grouped expert megakernel vs the forced materialize-dense baseline
    (``ops.set_default_impl('unfused')``; a renamed cfg busts the jit
    caches so both paths really trace).  Informational at smoke scale —
    48×64 experts are overhead-dominated on CPU; the perf claim lives in
    :func:`moe_fused_latency`'s representative-size rows."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import lm as LM

    cfg = get_config("deepseek-v2-lite-16b").smoke
    params = LM.init_lm(jax.random.PRNGKey(0), cfg, jnp.float32)
    st = build_serve_params(params, CompressionPolicy(
        mode="compressed", min_weight_size=1024))
    toks = jnp.ones((4, 8), jnp.int32)
    max_new = 8
    prev = ops._DEFAULT_IMPL
    for path, cfg_v in (
            ("grouped_fused", cfg),
            ("unfused", dataclasses.replace(cfg,
                                            name=cfg.name + "-unfused"))):
        try:
            if path == "unfused":
                ops.set_default_impl("unfused")
            ops.DISPATCH_COUNTS.clear()
            t = time_call(lambda c=cfg_v: generate(
                st.params, c, toks, lut=st.lut, max_new=max_new),
                warmup=1, iters=3)
            disp = dict(ops.DISPATCH_COUNTS)
        finally:
            ops.set_default_impl(prev)
        tps = toks.shape[0] * max_new / t
        emit(f"latency.moe_generate.{path}_s", f"{t:.4f}",
             f"deepseek-v2-lite smoke, {tps:.1f} tok/s")
        if rows is not None:
            rows.append(dict(bench="moe_generate",
                             arch="deepseek-v2-lite-smoke", path=path,
                             wall_s=t, tokens_per_s=tps, dispatch=disp))


def moe_json(path: str = "BENCH_moe.json"):
    """Machine-readable MoE artifact: grouped fused vs materialize-dense,
    op-level (tokens/s + weight bytes moved) and generate-level."""
    rows: list = []
    moe_fused_latency(rows)
    moe_generate_latency(rows)
    payload = {"schema": 1, "bench": "moe",
               "backend": jax.default_backend(),
               "host_devices": jax.device_count(), "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    emit("moe.json_rows", str(len(rows)), path)
    return payload


def latency_json(path: str = "BENCH_latency.json"):
    """Machine-readable latency artifact: fused vs unfused, single-device
    vs shard-mapped — the seed of the perf trajectory CI tracks."""
    rows: list = []
    fused_latency(rows)
    sharded_fused_latency(rows)
    payload = {"schema": 1, "bench": "latency",
               "backend": jax.default_backend(),
               "host_devices": jax.device_count(), "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    emit("latency.json_rows", str(len(rows)), path)
    return payload


def main():
    serving_latency()
    kernel_latency()
    fused_latency()
    sharded_fused_latency()
    moe_fused_latency()
    moe_generate_latency()


if __name__ == "__main__":
    main()
