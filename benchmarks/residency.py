"""Tiered expert residency benchmark — hit rates, stalls, throughput.

Sweeps the per-layer HBM expert-cache capacity {all, half, 1} of
``serve.residency.ResidencyManager`` against the fully-resident baseline
on a briefly-trained deepseek smoke model (the repo's MoE routing
trace).  Measured per capacity:

  * bitwise parity vs the fully-resident ``generate`` (asserted, not
    just reported — the residency acceptance bar);
  * hit rate and prefetch-hit rate (the routing-aware layer-ahead
    prefetcher must land nonzero prefetch hits on the deepseek trace —
    asserted whenever the cache is actually constrained);
  * synchronous-fetch stall per miss (ms) and bytes fetched host→HBM;
  * tokens/s vs the fully-resident path (the cost of tiering).

``residency_json`` bundles the sweep into ``BENCH_residency.json`` for
the CI artifact trail (see the residency-smoke job).
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np
import jax

from repro.core.policy import CompressionPolicy
from repro.serve.context import ServeContext
from repro.serve.engine import build_serve_params, generate
from repro.serve.residency import RESIDENCY_COUNTS, ResidencyManager

from .common import emit, time_call, trained_tiny_model


def residency_sweep(rows: list | None = None, *,
                    arch: str = "deepseek-v2-lite-16b", seed: int = 0,
                    max_new: int = 16):
    """Capacity sweep of the tiered expert cache; returns the row list."""
    cfg, params, _ = trained_tiny_model(arch, steps=20, seed=seed)
    # dropless routing so resident vs tiered parity is token-exact
    cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    st = build_serve_params(params, CompressionPolicy(
        mode="compressed", min_weight_size=1024))
    ctx = ServeContext.from_state(cfg, st)
    rng = np.random.RandomState(seed)
    prompt = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)[None, :]
    max_len = prompt.shape[1] + max_new

    def run(c):
        return generate(st.params, cfg, prompt, ctx=c, max_new=max_new,
                        max_len=max_len)

    ref = np.asarray(run(ctx))
    base_t = time_call(run, ctx, warmup=1, iters=3)
    out_rows = rows if rows is not None else []
    out_rows.append(dict(
        bench="residency", arch=arch, seed=seed, capacity="all-resident",
        experts=cfg.n_experts, tokens_per_s=max_new / base_t,
        parity_ok=True, hit_rate=None, prefetch_hit_rate=None,
        stall_per_miss_ms=0.0, bytes_fetched=0, evictions=0, replays=0))
    emit("residency.resident_tokens_per_s", f"{max_new / base_t:.2f}",
         f"{arch} fully-resident baseline")

    caps = list(dict.fromkeys(
        [cfg.n_experts, max(cfg.n_experts // 2, 1), 1]))
    for cap in caps:
        mgr = ResidencyManager(st, cfg, capacity=cap)
        tctx = dataclasses.replace(ctx, residency=mgr)
        out = np.asarray(run(tctx))         # also warms the tiered traces
        assert np.array_equal(out, ref), \
            f"tiered output diverged at capacity {cap}"
        RESIDENCY_COUNTS.clear()
        mgr.reset_stats()
        t = time_call(run, tctx, warmup=0, iters=3)
        snap = mgr.snapshot()
        if cap < cfg.n_experts:
            # the routing-aware acceptance bar: layer-ahead prefetch must
            # land hits on the deepseek routing trace
            assert snap["prefetch_hit"] > 0, snap
        row = dict(
            bench="residency", arch=arch, seed=seed, capacity=cap,
            experts=cfg.n_experts, tokens_per_s=max_new / t,
            parity_ok=True, hit_rate=snap["hit_rate"],
            prefetch_hit_rate=snap["prefetch_hit_rate"],
            stall_per_miss_ms=snap["stall_per_miss_ms"],
            bytes_fetched=snap["bytes_fetched"], evictions=snap["evict"],
            replays=snap["replay"], misses=snap["miss"],
            sync_fetches=snap["sync_fetch"],
            slowdown_vs_resident=t / base_t,
            cache_mib=cap * snap["layers"] * snap["bytes_per_expert"]
            / 2**20)
        out_rows.append(row)
        emit(f"residency.cap{cap}.tokens_per_s", f"{max_new / t:.2f}",
             f"slowdown x{t / base_t:.2f} vs resident")
        emit(f"residency.cap{cap}.hit_rate", f"{snap['hit_rate']}",
             f"prefetch_hit_rate={snap['prefetch_hit_rate']}")
        emit(f"residency.cap{cap}.stall_per_miss_ms",
             f"{snap['stall_per_miss_ms']}",
             f"misses={snap['miss']} bytes={snap['bytes_fetched']}")
    return out_rows


def residency_json(path: str = "BENCH_residency.json", *,
                   arch: str = "deepseek-v2-lite-16b", seed: int = 0):
    """Machine-readable tiered-residency artifact."""
    rows: list = []
    residency_sweep(rows, arch=arch, seed=seed)
    payload = {"schema": 1, "bench": "residency",
               "backend": jax.default_backend(),
               "host_devices": jax.device_count(),
               "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    emit("residency.json_rows", str(len(rows)), path)
    return payload


if __name__ == "__main__":
    residency_json()
