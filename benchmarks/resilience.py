"""Resilience-path costs: integrity-verify overhead and per-rung serving.

Two questions an operator needs numbers for before turning the knobs on:

  * **What does ``--verify`` cost at boot?** ``verify_overhead`` times the
    manifest build + 'full'/'fast' re-hash + device invariant check against
    the pack time itself, per model bytes.  Full verification re-hashes
    every byte and must still be a small fraction of packing (the
    acceptance bar: < 10% of pack wall time); 'fast' is the sampled-digest
    bound for very large artifacts.
  * **What does each degradation rung cost while serving?**
    ``ladder_generate`` measures end-to-end greedy ``generate`` tokens/s on
    every rung of the ladder — fused megakernel, two-step unfused,
    pure-jnp materialize — via the session impl lever with a renamed cfg
    (jit caches key on the config), i.e. exactly how ``ResilientEngine``
    re-traces a fallback.

A third, on the request level: **what does quarantining a poisoned
request cost its batch-mates?** ``quarantine_recovery`` serves the same
3-request trace clean and with one slot poisoned
(``FaultInjector.slot_fault``), and reports the drain-time ratio — the
price of the bisect replays plus the survivors' resume re-prefills —
alongside the exactly-one-refused accounting.

``resilience_json`` bundles all of it into ``BENCH_resilience.json`` for
the CI artifact trail.
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.integrity import (build_manifest, check_invariants,
                                  verify_serve_state)
from repro.core.policy import CompressionPolicy
from repro.kernels import ops
from repro.serve.context import ServeContext
from repro.serve.engine import build_serve_params, generate
from repro.serve.resilience import ResiliencePolicy
from repro.serve.scheduler import Engine, Request
from repro.testing import FaultInjector

from .common import emit, trained_tiny_model

_LADDER = ResiliencePolicy().ladder


def verify_overhead(rows: list | None = None, steps: int = 40):
    """Manifest build + verify('full'/'fast') + invariants vs pack time."""
    cfg, params, _ = trained_tiny_model(steps=steps)
    pol = CompressionPolicy(mode="compressed", min_weight_size=1024)

    t0 = time.perf_counter()
    st = build_serve_params(params, pol, manifest=False)
    t_pack = time.perf_counter() - t0

    t0 = time.perf_counter()
    mf = build_manifest(st.params, st.lut, st.table)
    t_manifest = time.perf_counter() - t0
    st = dataclasses.replace(st, manifest=mf)

    t0 = time.perf_counter()
    rep_full = verify_serve_state(st, level="full")
    t_full = time.perf_counter() - t0
    assert rep_full.ok, rep_full.corrupt

    t0 = time.perf_counter()
    rep_fast = verify_serve_state(st, level="fast")
    t_fast = time.perf_counter() - t0
    assert rep_fast.ok, rep_fast.corrupt

    t0 = time.perf_counter()
    rep_inv = check_invariants(st)
    t_inv = time.perf_counter() - t0
    assert rep_inv.ok, rep_inv.corrupt

    model_bytes = mf["total_bytes"]
    emit("resilience.pack_s", f"{t_pack:.3f}",
         f"{model_bytes/2**20:.2f} MiB compressed artifact")
    emit("resilience.manifest_build_s", f"{t_manifest:.4f}",
         f"{t_manifest/t_pack:.3%} of pack")
    emit("resilience.verify_full_s", f"{t_full:.4f}",
         f"{t_full/t_pack:.3%} of pack, {rep_full.bytes_hashed} B hashed")
    emit("resilience.verify_fast_s", f"{t_fast:.4f}",
         f"{t_fast/t_pack:.3%} of pack, sampled digests")
    emit("resilience.invariants_s", f"{t_inv:.4f}",
         f"device-side structural check, {rep_inv.checked} planes")
    if rows is not None:
        rows.append(dict(bench="verify_overhead", model_bytes=model_bytes,
                         pack_s=t_pack, manifest_build_s=t_manifest,
                         verify_full_s=t_full, verify_fast_s=t_fast,
                         invariants_s=t_inv,
                         full_bytes_hashed=rep_full.bytes_hashed,
                         fast_bytes_hashed=rep_fast.bytes_hashed,
                         full_over_pack=t_full / t_pack,
                         fast_over_pack=t_fast / t_pack))
    return t_full / t_pack


def ladder_generate(rows: list | None = None):
    """Greedy generate tokens/s on each degradation rung (llama smoke).

    Each fallback rung re-traces under a suffixed cfg name with the impl
    lever pinned — the same mechanics ``ResilientEngine._run_rung`` uses,
    so these are the real costs of serving degraded."""
    cfg, params, _ = trained_tiny_model(steps=20)
    st = build_serve_params(params, CompressionPolicy(
        mode="compressed", min_weight_size=1024))
    toks = jnp.ones((4, 8), jnp.int32)
    max_new = 8
    prev = ops._DEFAULT_IMPL
    base = None
    for rung in _LADDER:
        cfg_v = (cfg if rung == _LADDER[0] else
                 dataclasses.replace(cfg, name=f"{cfg.name}+{rung}"))
        try:
            if rung != _LADDER[0]:
                ops.set_default_impl(rung)
            ops.DISPATCH_COUNTS.clear()
            ctx = ServeContext.from_state(cfg_v, st)
            # warmup (trace) + 3 timed calls
            jax.block_until_ready(generate(st.params, cfg_v, toks,
                                           ctx=ctx, max_new=max_new))
            ts = []
            for _ in range(3):
                t0 = time.perf_counter()
                jax.block_until_ready(generate(st.params, cfg_v, toks,
                                               ctx=ctx, max_new=max_new))
                ts.append(time.perf_counter() - t0)
            t = sorted(ts)[len(ts) // 2]
            disp = dict(ops.DISPATCH_COUNTS)
        finally:
            ops.set_default_impl(prev)
        tps = toks.shape[0] * max_new / t
        base = base or tps
        emit(f"resilience.generate8.{rung}_s", f"{t:.4f}",
             f"{tps:.1f} tok/s ({tps/base:.2f}x fused rung)")
        if rows is not None:
            rows.append(dict(bench="ladder_generate", rung=rung, wall_s=t,
                             tokens_per_s=tps, rel_to_fused=tps / base,
                             dispatch=disp))


def quarantine_recovery(rows: list | None = None, *, seed: int = 0):
    """Drain-time cost of quarantining one poisoned request out of a
    3-request batch, vs the same trace served clean.

    The poisoned run pays the bisect's masked replays (reusing the jitted
    step — no retrace) plus the survivors' resume re-prefills; the clean
    run is the baseline.  Survivor outputs must be bitwise-identical
    across the two runs — the quarantine may cost time, never tokens."""
    cfg, params, _ = trained_tiny_model(steps=20)
    st = build_serve_params(params, CompressionPolicy(
        mode="compressed", min_weight_size=1024))
    ctx = ServeContext.from_state(cfg, st)
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in rng.randint(4, 7, 3)]

    def run(poison: bool):
        eng = Engine(ctx, st.params, n_slots=3, max_len=16, page_size=8)
        for i, p in enumerate(prompts):
            eng.submit(Request(tokens=p, max_new=6, rid=i))
        t0 = time.perf_counter()
        if poison:
            # arm only until the quarantine fires so the slot's next
            # occupant (a resumed survivor) decodes clean
            with FaultInjector(seed).slot_fault(slot=1, nth=1):
                while not any(c.finished == "refused"
                              for c in eng.completions):
                    eng.step()
        eng.drain()
        jax.block_until_ready(eng.pool.pages)
        return time.perf_counter() - t0, eng

    run(False)                          # warm the traces
    t_clean, eng_clean = run(False)
    t_poison, eng_poison = run(True)

    refused = [c for c in eng_poison.completions if c.finished == "refused"]
    assert len(refused) == 1, [c.finished for c in eng_poison.completions]
    clean_by_rid = {c.rid: c for c in eng_clean.completions}
    survivors_ok = all(
        np.array_equal(c.tokens, clean_by_rid[c.rid].tokens)
        for c in eng_poison.completions if c.finished != "refused")
    assert survivors_ok, "survivor tokens diverged from the clean run"

    ratio = t_poison / t_clean
    emit("resilience.quarantine_drain_s", f"{t_poison:.4f}",
         f"{ratio:.2f}x clean drain ({t_clean:.4f}s), 1 of 3 refused")
    if rows is not None:
        rows.append(dict(bench="quarantine_recovery", n_requests=3,
                         refused=len(refused), clean_s=t_clean,
                         poisoned_s=t_poison, poisoned_over_clean=ratio,
                         survivor_parity_ok=bool(survivors_ok),
                         resumes=max(c.resumed
                                     for c in eng_poison.completions)))
    return ratio


def resilience_json(path: str = "BENCH_resilience.json"):
    """Machine-readable resilience artifact: verify overhead vs model
    bytes + per-rung generate throughput + quarantine recovery cost."""
    rows: list = []
    full_over_pack = verify_overhead(rows)
    ladder_generate(rows)
    quarantine_recovery(rows)
    payload = {"schema": 1, "bench": "resilience",
               "backend": jax.default_backend(),
               "host_devices": jax.device_count(),
               "full_verify_over_pack": full_over_pack,
               "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    emit("resilience.json_rows", str(len(rows)), path)
    return payload


def main():
    resilience_json()


if __name__ == "__main__":
    main()
