"""Memory-pressure benchmark — the governor's reclaim/regrow ladder
under seeded budget traces.

The deployment regime is a 4–8 GB unified-memory edge device whose HBM
budget moves at runtime (jetsam-style OS reclaim).  Two traces:

``pressure_sweep`` serves a staggered request mix on the continuous-
batching engine while ``FaultInjector.memory_pressure`` replays each
seeded trace kind (step / spike / ramp / oscillate) through the
``serve.governor._os_pressure`` seam.  Measured per kind:

  * tokens/s over the drain and how far it degrades vs the unpressured
    baseline;
  * full accounting — every submission ends as a ``Completion`` with
    ``finished`` in {eos, max_new, shed, deadline, refused, pressure}
    (asserted);
  * survivor parity — every ordinary finisher is bitwise-equal to
    one-shot ``generate`` (asserted: pressure moves where KV lives and
    when requests run, never what they compute);
  * hysteresis damping — ``plan_changes`` and the re-trace count stay
    bounded by the number of sustained band crossings, never
    per-signal-flip (asserted: retraces <= 1 + plan_changes).

``reclaim_ladder`` walks all four rungs explicitly on a tiered MoE
engine (deepseek smoke + ``ResidencyManager``): trim experts -> retire
KV pages (preempting an in-flight tenant) -> tighten admission ->
refuse new work, then a full regrow back to the boot plan.  Measured:
time-to-reclaim per rung (seconds, from ``MemoryGovernor.rung_latency``)
and the same accounting/parity bars.

``pressure_json`` bundles both into ``BENCH_pressure.json`` for the CI
artifact trail (see the serving-smoke job).
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np
import jax

from repro.core.policy import CompressionPolicy, device_budget
from repro.serve import engine as engine_mod
from repro.serve.context import ServeContext
from repro.serve.engine import build_serve_params, generate
from repro.serve.governor import MemoryGovernor
from repro.serve.residency import ResidencyManager
from repro.serve.resilience import FALLBACK_COUNTS, ResilientEngine
from repro.serve.scheduler import Engine, Request
from repro.testing import FaultInjector, PRESSURE_KINDS, pressure_trace

from .common import emit, trained_tiny_model

ACCOUNTED = {"eos", "max_new", "shed", "deadline", "refused", "pressure"}


def _serve_under_trace(cfg, st, trace, *, seed, n_requests=5):
    """Serve a staggered request mix while the governor ingests
    ``trace`` through the patched ``_os_pressure`` seam; returns
    (summary-dict, governor)."""
    ctx = ServeContext.from_state(cfg, st)
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(0, cfg.vocab_size,
                           int(rng.randint(4, 10))).astype(np.int32)
               for _ in range(n_requests)]
    max_news = rng.randint(4, 8, n_requests)
    arrivals = np.concatenate(
        [[0], np.cumsum(rng.poisson(2.0, n_requests - 1))])

    pool_probe = Engine(ctx, st.params, n_slots=3, max_len=16, page_size=8)
    pn = pool_probe.pool.page_nbytes()
    boot = pool_probe.pool.n_pages * pn
    del pool_probe
    gov = MemoryGovernor(device_budget(boot, expert_bytes=0, kv_bytes=boot))
    eng = Engine(ctx, st.params, n_slots=3, max_len=16, page_size=8,
                 governor=gov)

    inj = FaultInjector(seed)
    t0 = time.perf_counter()
    submitted = 0
    ctx_mgr = (inj.memory_pressure(trace, hold_last=True)
               if trace is not None else None)
    probe = ctx_mgr.__enter__() if ctx_mgr is not None else None
    try:
        while submitted < n_requests or eng.health()["occupied"] \
                or eng.health()["queued"]:
            while submitted < n_requests \
                    and eng.steps >= arrivals[submitted]:
                eng.submit(Request(tokens=prompts[submitted],
                                   max_new=int(max_news[submitted]),
                                   rid=submitted))
                submitted += 1
            eng.step()
    finally:
        if ctx_mgr is not None:
            ctx_mgr.__exit__(None, None, None)
    jax.block_until_ready(eng.pool.pages)
    wall = time.perf_counter() - t0

    by_rid = {c.rid: c for c in eng.completions}
    assert set(by_rid) == set(range(n_requests)), "unaccounted request"
    reasons = {c.rid: c.finished for c in eng.completions}
    assert all(r in ACCOUNTED for r in reasons.values()), reasons
    parity_ok = True
    for i, c in by_rid.items():
        if c.finished not in ("eos", "max_new"):
            continue
        ref = np.asarray(generate(st.params, cfg, prompts[i][None, :],
                                  ctx=ctx, max_new=int(max_news[i]),
                                  max_len=eng.pool.max_len))[0]
        parity_ok &= bool(np.array_equal(ref, c.tokens))
    assert parity_ok, "survivor output diverged from generate"
    n_tok = sum(c.n_generated for c in eng.completions)
    summary = dict(
        steps=eng.steps, wall_s=wall, tokens=n_tok,
        tokens_per_s=n_tok / wall, survivor_parity_ok=parity_ok,
        finished_reasons={r: sum(1 for v in reasons.values() if v == r)
                          for r in sorted(set(reasons.values()))},
        plan_changes=gov.plan_changes,
        polls=(probe.executions if probe is not None else 0),
        rung_latency_s=dict(gov.rung_latency))
    eng.close()
    return summary, gov


def pressure_sweep(rows: list | None = None, *,
                   arch: str = "llama3.2-1b", seed: int = 0,
                   n_steps: int = 48):
    """One seeded budget trace per kind; asserts accounting, survivor
    parity, and the hysteresis retrace bound."""
    cfg, params, _ = trained_tiny_model(arch, steps=20, seed=seed)
    st = build_serve_params(params, CompressionPolicy(
        mode="compressed", min_weight_size=1024))
    out_rows = rows if rows is not None else []

    # unpressured baseline, fresh trace-cache key
    cfg0 = dataclasses.replace(cfg, name=cfg.name + "-press-none")
    base, _ = _serve_under_trace(cfg0, st, None, seed=seed)
    base["bench"] = "pressure_sweep"
    base.update(arch=arch, seed=seed, kind="none", retraces=None)
    out_rows.append(base)
    emit("pressure.baseline_tokens_per_s", f"{base['tokens_per_s']:.1f}",
         "no pressure signal")

    probe = Engine(ServeContext.from_state(cfg0, st), st.params,
                   n_slots=3, max_len=16, page_size=8)
    pn = probe.pool.page_nbytes()
    boot = probe.pool.n_pages * pn
    del probe
    for kind in PRESSURE_KINDS:
        kcfg = dataclasses.replace(cfg, name=cfg.name + f"-press-{kind}")
        trace = pressure_trace(kind, boot_bytes=boot, low_bytes=3 * pn,
                               n_steps=n_steps, period=4, seed=seed)
        t_base = engine_mod.TRACE_COUNTS["generate_step"]
        summary, gov = _serve_under_trace(kcfg, st, trace, seed=seed)
        retraces = engine_mod.TRACE_COUNTS["generate_step"] - t_base
        # the hysteresis bar: re-traces track sustained band crossings
        # (plan changes), never the per-step signal flips
        assert retraces <= 1 + gov.plan_changes, (retraces,
                                                  gov.plan_changes)
        summary["bench"] = "pressure_sweep"
        summary.update(arch=arch, seed=seed, kind=kind, retraces=retraces,
                       trace_len=len(trace),
                       signal_flips=sum(1 for a, b in zip(trace, trace[1:])
                                        if a != b))
        out_rows.append(summary)
        emit(f"pressure.{kind}_tokens_per_s",
             f"{summary['tokens_per_s']:.1f}",
             f"plan_changes={gov.plan_changes} retraces={retraces} "
             f"flips={summary['signal_flips']}")
    return out_rows


def reclaim_ladder(rows: list | None = None, *,
                   arch: str = "deepseek-v2-lite-16b", seed: int = 0):
    """Walk every rung once on a tiered MoE engine and time it:
    trim experts -> retire KV (with preemption) -> tighten -> refuse,
    then regrow to the boot plan."""
    cfg, params, _ = trained_tiny_model(arch, steps=20, seed=seed)
    # dropless routing so survivor parity is token-exact
    cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts),
                              name=cfg.name + "-press-ladder")
    st = build_serve_params(params, CompressionPolicy(
        mode="compressed", min_weight_size=1024))
    ctx = ServeContext.from_state(cfg, st)
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(3)]
    refs = [np.asarray(generate(st.params, cfg, p[None, :], ctx=ctx,
                                max_new=10, max_len=32))[0]
            for p in prompts[:2]]

    mgr = ResidencyManager(st, cfg, capacity=3)
    unit = mgr.n_layers * mgr.bytes_per_expert
    reng = ResilientEngine(cfg, st, residency=mgr)
    eng = reng.scheduler(n_slots=2, max_len=32, page_size=8)
    pn = eng.pool.page_nbytes()
    kv_boot = eng.pool.n_pages * pn            # 8 pages, 4 per slot
    boot = 3 * unit + kv_boot
    gov = MemoryGovernor(device_budget(boot, expert_bytes=3 * unit,
                                       kv_bytes=kv_boot),
                         cooldown_steps=2)
    gov.attach(eng)
    eng.governor = gov
    base = {k: FALLBACK_COUNTS[k] for k in
            ("pressure_trim", "pressure_kv_retire", "pressure_preempt",
             "pressure_tighten", "pressure_refused", "pressure_regrow")}

    for i, p in enumerate(prompts[:2]):
        eng.submit(Request(tokens=p, max_new=10, rid=i))
    eng.step()                                  # both admitted
    # rung 1: trim the expert cache 3 -> 1
    gov.set_budget(boot - 2 * unit)
    eng.step()
    assert mgr.capacity == 1 and not mgr.prefetch_enabled
    # rung 2+3: retire half the KV pool; both slots are occupied, so the
    # governor must preempt one tenant; one backed slot left -> tighten
    gov.set_budget(unit + 4 * pn)
    eng.step()
    assert eng.pool.n_pages_usable == 4, eng.pool.n_pages_usable
    assert eng.max_queue == 1
    # rung 4: below min_viable -> refuse new work
    gov.set_budget(gov.refuse_below - 1)
    eng.step()
    assert gov.refusing
    eng.submit(Request(tokens=prompts[2], max_new=4, rid=2))
    assert next(c for c in eng.completions
                if c.rid == 2).finished == "pressure"
    # regrow: budget fully recovers; sustained for cooldown steps
    gov.set_budget(boot)
    for _ in range(gov.cooldown_steps + 1):
        eng.step()
    eng.drain()
    assert not gov.refusing and mgr.capacity == 3 and mgr.prefetch_enabled
    assert eng.pool.n_pages_usable == eng.pool.n_pages

    by_rid = {c.rid: c for c in eng.completions}
    parity_ok = all(np.array_equal(refs[i], by_rid[i].tokens)
                    for i in range(2))
    assert parity_ok, "preempted/survivor output diverged"
    delta = {k: FALLBACK_COUNTS[k] - base[k] for k in base}
    assert all(v >= 1 for v in delta.values()), delta
    lat = dict(gov.rung_latency)
    for rung in ("trim_experts", "retire_kv", "regrow_kv",
                 "regrow_experts"):
        assert rung in lat, lat
    eng.close()

    summary = dict(
        bench="reclaim_ladder", arch=arch, seed=seed,
        plan_changes=gov.plan_changes, fallback_delta=delta,
        survivor_parity_ok=parity_ok, resumed=by_rid[0].resumed
        + by_rid[1].resumed, rung_latency_s=lat,
        refuse_below_bytes=gov.refuse_below, boot_bytes=boot)
    for rung, dt in sorted(lat.items()):
        emit(f"pressure.latency_{rung}_ms", f"{dt * 1e3:.2f}",
             "time-to-reclaim" if rung.startswith(("trim", "retire"))
             else "time-to-regrow")
    emit("pressure.ladder_rungs", str(len(lat)),
         f"preempted+resumed={summary['resumed']} parity_ok={parity_ok}")
    if rows is not None:
        rows.append(summary)
    return summary


def pressure_json(path: str = "BENCH_pressure.json", *, seed: int = 0):
    """Machine-readable memory-pressure artifact."""
    rows: list = []
    pressure_sweep(rows, seed=seed)
    reclaim_ladder(rows, seed=seed)
    payload = {"schema": 1, "bench": "pressure",
               "backend": jax.default_backend(),
               "host_devices": jax.device_count(),
               "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    emit("pressure.json_rows", str(len(rows)), path)
    return payload


def main():
    pressure_json()


if __name__ == "__main__":
    main()
