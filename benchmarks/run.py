"""Benchmark harness — one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--only compression,...]

Emits ``name,value,derived`` CSV rows:
  compression — paper Table 1 (size triple, ratios)
  accuracy    — paper Tables 2-4 (dense/quant/compressed parity + latency)
  bitwidth    — paper §3 ablation (ternary..8bit naive, GPTQ)
  latency     — paper §5 CPU latency discussion + kernel microbench
  roofline    — deliverable (g): three terms per (arch × shape × mesh)
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = ["compression", "accuracy", "bitwidth", "latency", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(MODULES))
    args = ap.parse_args()
    picked = args.only.split(",") if args.only else MODULES

    failures = 0
    for name in picked:
        print(f"# === benchmarks.{name} ===", flush=True)
        t0 = time.monotonic()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
        except Exception:  # noqa: BLE001 — keep the harness sweeping
            failures += 1
            print(f"{name}.ERROR,1,", flush=True)
            traceback.print_exc()
        print(f"# {name} done in {time.monotonic()-t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
