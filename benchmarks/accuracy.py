"""Paper Tables 2-4 — accuracy parity + latency for the evaluation triple
(dense / Quantized / Compressed) on multiple-choice tasks.

MMLU/ARC are not available offline; the *pipeline* is reproduced exactly
(paper §5): prompts are tokenized, the model scores the log-likelihood of
each answer option, argmax is the prediction, accuracy + per-example
latency are reported per weight mode.  Tasks are synthetic multiple-choice
items derived from the markov stream the model was trained on — so the
dense model is genuinely above chance, and the paper's claims (quantized ≈
dense, compressed ≡ quantized, compressed adds decode latency) are
checkable.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.policy import CompressionPolicy
from repro.models import lm as LM
from repro.serve.engine import build_serve_params

from .common import emit, trained_tiny_model


def _make_items(data, n_items: int = 64, prompt_len: int = 24,
                n_choices: int = 4, seed: int = 123):
    """Multiple-choice items: prompt = real stream prefix; correct answer =
    true continuation (4 tokens); distractors = continuations from other
    streams."""
    rng = np.random.default_rng(seed)
    items = []
    for i in range(n_items):
        b = data.batch_at(1000 + i)
        toks = np.asarray(b["tokens"])[0]
        prompt = toks[:prompt_len]
        answer = toks[prompt_len:prompt_len + 4]
        distract = [np.asarray(data.batch_at(5000 + i * 7 + j)["tokens"])[0,
                    prompt_len:prompt_len + 4] for j in range(n_choices - 1)]
        options = [answer] + distract
        order = rng.permutation(n_choices)
        items.append({
            "prompt": prompt,
            "options": [options[k] for k in order],
            "label": int(np.argwhere(order == 0)[0][0]),
        })
    return items


def _loglik(cfg, params, lut, prompt, option, fwd):
    seq = jnp.asarray(np.concatenate([prompt, option]))[None]
    logits = fwd(params, lut, seq)
    lp = jax.nn.log_softmax(logits[0, len(prompt) - 1:-1].astype(jnp.float32))
    ll = lp[jnp.arange(len(option)), jnp.asarray(option)]
    return float(jnp.sum(ll))


def evaluate(cfg, params, lut, items):
    @jax.jit
    def fwd(p, l, seq):
        logits, _, _ = LM.forward(p, cfg, seq, lut=l)
        return logits

    # warmup compile
    _loglik(cfg, params, lut, items[0]["prompt"], items[0]["options"][0], fwd)
    correct, lat = 0, []
    for it in items:
        t0 = time.perf_counter()
        scores = [_loglik(cfg, params, lut, it["prompt"], o, fwd)
                  for o in it["options"]]
        lat.append(time.perf_counter() - t0)
        correct += int(np.argmax(scores) == it["label"])
    return correct / len(items), float(np.mean(lat))


def main():
    cfg, params, data = trained_tiny_model(steps=150)
    items = _make_items(data)

    modes = {
        "dense": (params, None),
    }
    for mode in ("quant", "compressed"):
        st = build_serve_params(params, CompressionPolicy(
            mode=mode, min_weight_size=1024))
        modes[mode] = (st.params, st.lut)

    accs = {}
    for mode, (p, lut) in modes.items():
        acc, lat = evaluate(cfg, p, lut, items)
        accs[mode] = acc
        emit(f"tables234.{mode}.accuracy_pct", f"{acc*100:.2f}",
             "synthetic 4-choice (chance=25)")
        emit(f"tables234.{mode}.latency_s", f"{lat:.4f}", "per-example, CPU")
    emit("tables234.parity.quant_vs_dense_pp",
         f"{(accs['quant']-accs['dense'])*100:+.2f}",
         "paper: -0.05 pp (1B MMLU)")
    emit("tables234.parity.compressed_vs_quant_pp",
         f"{(accs['compressed']-accs['quant'])*100:+.2f}",
         "paper: 0.00 pp (lossless codec)")


if __name__ == "__main__":
    main()
