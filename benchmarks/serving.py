"""Mixed-traffic serving benchmark — the continuous-batching engine
under a seeded request trace.

One ``serve.Engine`` over a small paged-KV slot pool serves a trace of
overlapping requests with staggered Poisson arrivals and varied
prompt/decode lengths — the workload the fixed-batch ``generate`` cannot
express.  Measured per trace:

  * tokens/s over the whole drain (wall clock);
  * per-request latency (submit→finish) p50/p95, in engine steps and
    seconds;
  * slot occupancy mean/max + how many requests joined mid-decode —
    occupancy_max > 1 with joined_mid_decode >= 1 is the continuous-
    batching acceptance bar (requests actually overlap);
  * ``parity_ok`` — every served output is bitwise-equal to a one-shot
    ``generate`` of the same prompt at the pool's cache length (the
    correctness bar; asserted, not just reported).

``overload_trace`` is the overload/fault smoke: a bounded queue that
sheds, TTLs that expire, a high-priority arrival that preempts an
in-flight request off an overcommitted page pool, and a poisoned request
that is quarantined by bisection — the whole trace must *drain without
raising*, every submitted request accounted for by an explicit
completion reason, the counts mirrored in ``FALLBACK_COUNTS``, and every
ordinary finisher (including the preempted-then-resumed one) still
bitwise-equal to one-shot ``generate``.

``serving_json`` bundles both into ``BENCH_serving.json`` for the CI
artifact trail (see the serving-smoke job).
"""
from __future__ import annotations

import json
import time

import numpy as np
import jax

from repro.core.policy import CompressionPolicy
from repro.serve.context import ServeContext
from repro.serve.engine import build_serve_params, generate
from repro.serve.resilience import FALLBACK_COUNTS
from repro.serve.scheduler import Engine, Request
from repro.testing import FaultInjector

from .common import emit, trained_tiny_model


def serve_trace(rows: list | None = None, *, arch: str = "llama3.2-1b",
                n_requests: int = 8, n_slots: int = 3, seed: int = 0):
    """Serve one seeded mixed-traffic trace; returns the summary dict."""
    cfg, params, _ = trained_tiny_model(arch, steps=20, seed=seed)
    st = build_serve_params(params, CompressionPolicy(
        mode="compressed", min_weight_size=1024))
    ctx = ServeContext.from_state(cfg, st)

    rng = np.random.RandomState(seed)
    prompt_lens = rng.randint(4, 12, n_requests)
    max_news = rng.randint(3, 9, n_requests)
    arrivals = np.concatenate([[0], np.cumsum(rng.poisson(1.5, n_requests - 1))])
    prompts = [rng.randint(0, cfg.vocab_size, p).astype(np.int32)
               for p in prompt_lens]
    max_len = int(prompt_lens.max() + max_news.max())

    eng = Engine(ctx, st.params, n_slots=n_slots, max_len=max_len)
    # warm the traces so the timed drain measures steady-state serving
    eng.submit(Request(tokens=prompts[0], max_new=2, rid=-1))
    eng.drain()
    eng.steps = 0
    eng.completions.clear()
    eng.reset_stats()

    submit_wall = {}
    t0 = time.perf_counter()
    submitted = 0
    while submitted < n_requests or eng.health()["occupied"] \
            or eng.health()["queued"]:
        while submitted < n_requests and eng.steps >= arrivals[submitted]:
            eng.submit(Request(tokens=prompts[submitted],
                               max_new=int(max_news[submitted]),
                               rid=submitted))
            submit_wall[submitted] = time.perf_counter()
            submitted += 1
        eng.step()
    jax.block_until_ready(eng.pool.pages)
    wall = time.perf_counter() - t0

    by_rid = {c.rid: c for c in eng.completions}
    lat_steps, lat_s, parity_ok = [], [], True
    for i in range(n_requests):
        c = by_rid[i]
        lat_steps.append(c.finished_step - c.submitted_step + 1)
        # finish wall time ~ proportional share of the drain; per-request
        # wall is measured from submit to the step that completed it
        lat_s.append(wall * lat_steps[-1] / max(eng.steps, 1))
        ref = np.asarray(generate(st.params, cfg, prompts[i][None, :],
                                  ctx=ctx, max_new=int(max_news[i]),
                                  max_len=eng.pool.max_len))[0]
        parity_ok &= bool(np.array_equal(ref, c.tokens))

    h = eng.health()
    n_tok = sum(by_rid[i].n_generated for i in range(n_requests))
    summary = dict(
        bench="serve_trace", arch=arch, n_requests=n_requests,
        n_slots=n_slots, seed=seed, steps=h["steps"], wall_s=wall,
        tokens=n_tok, tokens_per_s=n_tok / wall,
        latency_p50_steps=float(np.percentile(lat_steps, 50)),
        latency_p95_steps=float(np.percentile(lat_steps, 95)),
        latency_p50_s=float(np.percentile(lat_s, 50)),
        latency_p95_s=float(np.percentile(lat_s, 95)),
        occupancy_mean=h["occupancy_mean"],
        occupancy_max=h["occupancy_max"],
        joined_mid_decode=h["joined_mid_decode"],
        parity_ok=parity_ok)
    # the continuous-batching acceptance bar
    assert summary["parity_ok"], "engine output diverged from generate"
    assert summary["occupancy_max"] > 1, "requests never overlapped"
    assert summary["joined_mid_decode"] >= 1, "no mid-decode admission"
    emit("serving.tokens_per_s", f"{summary['tokens_per_s']:.1f}",
         f"{n_requests} reqs, {n_slots} slots, occ_max="
         f"{summary['occupancy_max']}")
    emit("serving.latency_p50_steps", f"{summary['latency_p50_steps']:.1f}",
         f"p95={summary['latency_p95_steps']:.1f}")
    emit("serving.joined_mid_decode", str(summary["joined_mid_decode"]),
         f"parity_ok={parity_ok}")
    if rows is not None:
        rows.append(summary)
    return summary


def overload_trace(rows: list | None = None, *, arch: str = "llama3.2-1b",
                   seed: int = 0):
    """Overload + fault smoke: the request-level robustness layer end to
    end, on a deterministic trace.

    Phase 1 runs an *overcommitted* engine (4 pages back only 2 of 3
    slots) with a bounded queue: one submission sheds, one queued request
    TTL-expires, and a priority-1 arrival preempts the youngest in-flight
    request off its pages — which later resumes and must still match
    one-shot ``generate`` bitwise.  Phase 2 poisons one slot of a healthy
    3-request batch via ``FaultInjector.slot_fault``: exactly one request
    is refused by the quarantine bisect, the survivors resume and finish
    bitwise-clean.  The whole trace must drain without raising, with every
    lifecycle event mirrored in ``FALLBACK_COUNTS``.
    """
    cfg, params, _ = trained_tiny_model(arch, steps=20, seed=seed)
    st = build_serve_params(params, CompressionPolicy(
        mode="compressed", min_weight_size=1024))
    ctx = ServeContext.from_state(cfg, st)
    rng = np.random.RandomState(seed + 1)
    prompts = [rng.randint(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in rng.randint(4, 7, 9)]
    base = {k: FALLBACK_COUNTS[k]
            for k in ("shed", "expired", "preempt", "quarantine")}

    def check_parity(eng, rid, prompt, max_new):
        c = next(c for c in eng.completions if c.rid == rid)
        ref = np.asarray(generate(st.params, cfg, prompt[None, :], ctx=ctx,
                                  max_new=max_new,
                                  max_len=eng.pool.max_len))[0]
        return bool(np.array_equal(ref, c.tokens))

    # -- phase 1: shed / expire / preempt on an overcommitted pool ------
    # page_size=8, max_len=16 -> pages_per_slot=2; n_pages=4 backs only
    # 2 of the 3 slots, so "free slot" never implies "free pages".
    eng = Engine(ctx, st.params, n_slots=3, max_len=16, page_size=8,
                 n_pages=4, max_queue=2, shed_policy="reject-new")
    long_new = 16 - len(prompts[0])        # outlasts the whole trace
    eng.submit(Request(tokens=prompts[0], max_new=long_new, rid=0))
    eng.submit(Request(tokens=prompts[1], max_new=3, rid=1))
    eng.step()                      # r0+r1 admitted; pool now exhausted
    eng.submit(Request(tokens=prompts[2], max_new=4, rid=2, ttl_steps=2))
    eng.submit(Request(tokens=prompts[3], max_new=4, rid=3))
    eng.submit(Request(tokens=prompts[4], max_new=4, rid=4))  # full -> shed
    while not any(c.rid == 1 for c in eng.completions):
        eng.step()                  # r1 finishes, releasing its pages
    eng.step()                      # r2 takes them; it TTL-expires soon
    eng.submit(Request(tokens=prompts[5], max_new=4, rid=5, priority=1))
    eng.drain()                     # r5 preempts the youngest; victim resumes
    h1 = eng.health()
    by_reason = {c.rid: c.finished for c in eng.completions}
    assert by_reason[4] == "shed", by_reason
    assert by_reason[2] == "deadline", by_reason
    assert h1["preempted"] >= 1 and h1["resumed"] >= 1, h1
    resumed_max = max(c.resumed for c in eng.completions)
    parity_ok = all(
        check_parity(eng, r, prompts[r], m)
        for r, m in [(0, long_new), (1, 3), (3, 4), (5, 4)])

    # -- phase 2: poisoned-request quarantine on a healthy pool ---------
    eng2 = Engine(ctx, st.params, n_slots=3, max_len=16, page_size=8)
    for i in range(3):
        eng2.submit(Request(tokens=prompts[6 + i], max_new=6, rid=10 + i))
    inj = FaultInjector(seed)
    # arm only until the quarantine fires, so the slot's next occupant
    # (a resumed survivor) decodes clean
    with inj.slot_fault(slot=1, nth=1):
        while not any(c.finished == "refused" for c in eng2.completions):
            eng2.step()
    eng2.drain()
    h2 = eng2.health()
    refused = [c for c in eng2.completions if c.finished == "refused"]
    assert len(refused) == 1, [c.finished for c in eng2.completions]
    survivors = [c for c in eng2.completions if c.finished != "refused"]
    assert len(survivors) == 2 and all(c.resumed >= 1 for c in survivors)
    parity_ok &= all(
        check_parity(eng2, 10 + i, prompts[6 + i], 6)
        for i in range(3) if 10 + i != refused[0].rid)

    delta = {k: FALLBACK_COUNTS[k] - base[k] for k in base}
    assert delta["shed"] >= 1 and delta["expired"] >= 1, delta
    assert delta["preempt"] >= 1 and delta["quarantine"] >= 1, delta
    assert parity_ok, "resumed/survivor output diverged from generate"

    summary = dict(
        bench="overload_trace", arch=arch, seed=seed,
        queue_peak=h1["queue_peak"], shed=h1["shed"],
        shed_rate=h1["shed"] / 6.0, expired=h1["expired"],
        preempted=h1["preempted"], resumed=h1["resumed"],
        max_resumes=resumed_max, quarantined=h2["quarantined"],
        refused=len(refused), survivor_parity_ok=parity_ok,
        fallback_delta=delta, steps_overload=h1["steps"],
        steps_quarantine=h2["steps"])
    emit("serving.overload_queue_peak", str(summary["queue_peak"]),
         f"shed={summary['shed']} expired={summary['expired']} "
         f"preempted={summary['preempted']}")
    emit("serving.overload_shed_rate", f"{summary['shed_rate']:.2f}",
         "reject-new, max_queue=2")
    emit("serving.quarantine_refused", str(summary["refused"]),
         f"survivors resumed clean, parity_ok={parity_ok}")
    if rows is not None:
        rows.append(summary)
    return summary


def serving_json(path: str = "BENCH_serving.json", *,
                 arch: str = "llama3.2-1b", n_requests: int = 8,
                 n_slots: int = 3, seed: int = 0):
    """Machine-readable mixed-traffic serving artifact."""
    rows: list = []
    serve_trace(rows, arch=arch, n_requests=n_requests, n_slots=n_slots,
                seed=seed)
    overload_trace(rows, arch=arch, seed=seed)
    payload = {"schema": 1, "bench": "serving",
               "backend": jax.default_backend(),
               "host_devices": jax.device_count(),
               "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    emit("serving.json_rows", str(len(rows)), path)
    return payload


def main():
    serving_json()


if __name__ == "__main__":
    main()
