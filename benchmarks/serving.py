"""Mixed-traffic serving benchmark — the continuous-batching engine
under a seeded request trace.

One ``serve.Engine`` over a small paged-KV slot pool serves a trace of
overlapping requests with staggered Poisson arrivals and varied
prompt/decode lengths — the workload the fixed-batch ``generate`` cannot
express.  Measured per trace:

  * tokens/s over the whole drain (wall clock);
  * per-request latency (submit→finish) p50/p95, in engine steps and
    seconds;
  * slot occupancy mean/max + how many requests joined mid-decode —
    occupancy_max > 1 with joined_mid_decode >= 1 is the continuous-
    batching acceptance bar (requests actually overlap);
  * ``parity_ok`` — every served output is bitwise-equal to a one-shot
    ``generate`` of the same prompt at the pool's cache length (the
    correctness bar; asserted, not just reported).

``serving_json`` bundles it into ``BENCH_serving.json`` for the CI
artifact trail (see the serving-smoke job).
"""
from __future__ import annotations

import json
import time

import numpy as np
import jax

from repro.core.policy import CompressionPolicy
from repro.serve.context import ServeContext
from repro.serve.engine import build_serve_params, generate
from repro.serve.scheduler import Engine, Request

from .common import emit, trained_tiny_model


def serve_trace(rows: list | None = None, *, arch: str = "llama3.2-1b",
                n_requests: int = 8, n_slots: int = 3, seed: int = 0):
    """Serve one seeded mixed-traffic trace; returns the summary dict."""
    cfg, params, _ = trained_tiny_model(arch, steps=20, seed=seed)
    st = build_serve_params(params, CompressionPolicy(
        mode="compressed", min_weight_size=1024))
    ctx = ServeContext.from_state(cfg, st)

    rng = np.random.RandomState(seed)
    prompt_lens = rng.randint(4, 12, n_requests)
    max_news = rng.randint(3, 9, n_requests)
    arrivals = np.concatenate([[0], np.cumsum(rng.poisson(1.5, n_requests - 1))])
    prompts = [rng.randint(0, cfg.vocab_size, p).astype(np.int32)
               for p in prompt_lens]
    max_len = int(prompt_lens.max() + max_news.max())

    eng = Engine(ctx, st.params, n_slots=n_slots, max_len=max_len)
    # warm the traces so the timed drain measures steady-state serving
    eng.submit(Request(tokens=prompts[0], max_new=2, rid=-1))
    eng.drain()
    eng.steps = 0
    eng.completions.clear()
    eng.stats = {"admitted": 0, "joined_mid_decode": 0, "occupancy": []}

    submit_wall = {}
    t0 = time.perf_counter()
    submitted = 0
    while submitted < n_requests or eng.health()["occupied"] \
            or eng.health()["queued"]:
        while submitted < n_requests and eng.steps >= arrivals[submitted]:
            eng.submit(Request(tokens=prompts[submitted],
                               max_new=int(max_news[submitted]),
                               rid=submitted))
            submit_wall[submitted] = time.perf_counter()
            submitted += 1
        eng.step()
    jax.block_until_ready(eng.pool.pages)
    wall = time.perf_counter() - t0

    by_rid = {c.rid: c for c in eng.completions}
    lat_steps, lat_s, parity_ok = [], [], True
    for i in range(n_requests):
        c = by_rid[i]
        lat_steps.append(c.finished_step - c.submitted_step + 1)
        # finish wall time ~ proportional share of the drain; per-request
        # wall is measured from submit to the step that completed it
        lat_s.append(wall * lat_steps[-1] / max(eng.steps, 1))
        ref = np.asarray(generate(st.params, cfg, prompts[i][None, :],
                                  ctx=ctx, max_new=int(max_news[i]),
                                  max_len=eng.pool.max_len))[0]
        parity_ok &= bool(np.array_equal(ref, c.tokens))

    h = eng.health()
    n_tok = sum(by_rid[i].n_generated for i in range(n_requests))
    summary = dict(
        bench="serve_trace", arch=arch, n_requests=n_requests,
        n_slots=n_slots, seed=seed, steps=h["steps"], wall_s=wall,
        tokens=n_tok, tokens_per_s=n_tok / wall,
        latency_p50_steps=float(np.percentile(lat_steps, 50)),
        latency_p95_steps=float(np.percentile(lat_steps, 95)),
        latency_p50_s=float(np.percentile(lat_s, 50)),
        latency_p95_s=float(np.percentile(lat_s, 95)),
        occupancy_mean=h["occupancy_mean"],
        occupancy_max=h["occupancy_max"],
        joined_mid_decode=h["joined_mid_decode"],
        parity_ok=parity_ok)
    # the continuous-batching acceptance bar
    assert summary["parity_ok"], "engine output diverged from generate"
    assert summary["occupancy_max"] > 1, "requests never overlapped"
    assert summary["joined_mid_decode"] >= 1, "no mid-decode admission"
    emit("serving.tokens_per_s", f"{summary['tokens_per_s']:.1f}",
         f"{n_requests} reqs, {n_slots} slots, occ_max="
         f"{summary['occupancy_max']}")
    emit("serving.latency_p50_steps", f"{summary['latency_p50_steps']:.1f}",
         f"p95={summary['latency_p95_steps']:.1f}")
    emit("serving.joined_mid_decode", str(summary["joined_mid_decode"]),
         f"parity_ok={parity_ok}")
    if rows is not None:
        rows.append(summary)
    return summary


def serving_json(path: str = "BENCH_serving.json", *,
                 arch: str = "llama3.2-1b", n_requests: int = 8,
                 n_slots: int = 3, seed: int = 0):
    """Machine-readable mixed-traffic serving artifact."""
    rows: list = []
    serve_trace(rows, arch=arch, n_requests=n_requests, n_slots=n_slots,
                seed=seed)
    payload = {"schema": 1, "bench": "serving",
               "backend": jax.default_backend(),
               "host_devices": jax.device_count(),
               "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    emit("serving.json_rows", str(len(rows)), path)
    return payload


def main():
    serving_json()


if __name__ == "__main__":
    main()
