"""Roofline analysis (deliverable g) — three terms per (arch × shape × mesh).

Reads the dry-run JSON records (results/dryrun/*.json) produced by
``repro.launch.dryrun`` and derives, per cell:

    compute_s    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory_s     = HLO_bytes_per_device / HBM_BW
    collective_s = collective_link_bytes_per_device / LINK_BW

plus MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (serve) and the
useful-compute ratio MODEL_FLOPS / (HLO_FLOPs × chips).

Hardware model (TPU v5e-class, per assignment):
    197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.

Ring-collective link-byte conversion (n = shard count of the op's mesh
axes; we use the mesh size as the bound): all-gather / reduce-scatter move
(n-1)/n of the result bytes over the busiest link; all-reduce = 2×(n-1)/n;
all-to-all = (n-1)/n; collective-permute = 1×.  HLO shapes are per-device
(post-SPMD), so byte sums are already per-chip.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config
from repro.launch.specs import SHAPES

from .common import emit

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s
LINK_BW = 50e9             # B/s per ICI link

RING_FACTOR = {
    "all-gather": 1.0, "reduce-scatter": 1.0, "all-reduce": 2.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
    "collective-broadcast": 1.0, "ragged-all-to-all": 1.0,
}


def model_flops(arch_id: str, shape_name: str) -> float:
    cfg = get_config(arch_id).full
    sh = SHAPES[shape_name]
    if sh["kind"] == "train":
        tokens = sh["seq"] * sh["batch"]
        return 6.0 * cfg.n_active_params() * tokens
    if sh["kind"] == "prefill":
        tokens = sh["seq"] * sh["batch"]
        return 2.0 * cfg.n_active_params() * tokens
    # decode: one new token per sequence
    return 2.0 * cfg.n_active_params() * sh["batch"]


def cell_roofline(rec: dict) -> dict | None:
    if not rec.get("ok") or "cost" not in rec:
        return None
    chips = 512 if rec["mesh"] == "multi" else 256
    # prefer the trip-weighted HLO walk (hlo_stats.hlo_cost); XLA's own
    # cost_analysis counts while bodies once
    hc = rec.get("hlo_cost", {})
    flops_dev = hc.get("flops") or rec["cost"].get("flops", 0.0)
    bytes_dev = hc.get("bytes") or rec["cost"].get("bytes accessed", 0.0)
    coll = rec.get("collectives", {})
    link_bytes = sum(RING_FACTOR.get(k, 1.0) * v
                     for k, v in coll.get("bytes_by_kind", {}).items())
    mf = model_flops(rec["arch"], rec["shape"])
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    coll_s = link_bytes / LINK_BW
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", coll_s), key=lambda kv: kv[1])
    total_hlo_flops = flops_dev * chips
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dom[0],
        "model_flops": mf,
        "useful_ratio": mf / total_hlo_flops if total_hlo_flops else 0.0,
        "hbm_gib_per_dev": rec.get("memory", {}).get(
            "total_hbm_bytes", 0) / 2**30,
        "step_s_bound": max(compute_s, memory_s, coll_s),
        "roofline_frac": (mf / chips / PEAK_FLOPS) /
                          max(compute_s, memory_s, coll_s, 1e-30),
    }


def load_all(dryrun_dir: str = "results/dryrun") -> list[dict]:
    out = []
    for fn in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(fn) as f:
            rec = json.load(f)
        r = cell_roofline(rec)
        if r:
            out.append(r)
    return out


def main():
    rows = load_all()
    if not rows:
        emit("roofline.error", 0, "no dry-run records; run "
             "PYTHONPATH=src python -m repro.launch.dryrun --all first")
        return
    for r in rows:
        key = f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}"
        emit(f"{key}.compute_s", f"{r['compute_s']:.4e}", "")
        emit(f"{key}.memory_s", f"{r['memory_s']:.4e}", "")
        emit(f"{key}.collective_s", f"{r['collective_s']:.4e}", "")
        emit(f"{key}.dominant", r["dominant"],
             f"useful_ratio={r['useful_ratio']:.3f} "
             f"roofline_frac={r['roofline_frac']:.3f}")


if __name__ == "__main__":
    main()
