"""Paper Table 1 — compression results (size triple + ratios).

Two measurements:
  1. *paper-mechanism @ tiny scale*: a briefly-trained smoke llama3.2 model,
     quantized per the paper, compressed with the paper-faithful escape
     codec AND the TPU blocked codec.  Real learned weight structure.
  2. *paper-scale statistics*: llama3.2-1B / 3B tensor shapes with
     synthetic trained-like (heavy-tailed) weights, sampled per tensor —
     reproduces the 1469→125 MB scale of Table 1 without shipping real
     checkpoints (none available offline; see EXPERIMENTS.md §Fidelity).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import codec, blocked_codec
from repro.core.quant import QuantConfig, quantize
from repro.core.policy import CompressionPolicy
from repro.serve.engine import build_serve_params

from .common import emit, trained_tiny_model, synthetic_trained_weights


def tiny_scale_table():
    cfg, params, _ = trained_tiny_model(steps=80)
    dense_bytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(params))

    st = build_serve_params(params, CompressionPolicy(mode="compressed",
                                                      min_weight_size=1024))
    quant_bytes = 0
    for _, leaf in jax.tree_util.tree_flatten_with_path(
            params, is_leaf=lambda x: hasattr(x, "shape"))[0]:
        if leaf.ndim >= 2 and leaf.size >= 1024:
            quant_bytes += leaf.size  # 1 B/weight
        else:
            quant_bytes += leaf.nbytes
    comp_bytes = st.stats["compressed"] + st.stats["quant"] + st.stats["dense"]
    emit("table1.tiny.dense_mb", f"{dense_bytes/2**20:.3f}",
         "fp32 smoke llama3.2 (trained 80 steps)")
    emit("table1.tiny.quant_mb", f"{quant_bytes/2**20:.3f}", "int8/weight")
    emit("table1.tiny.compressed_mb", f"{comp_bytes/2**20:.3f}",
         "blocked codec + table")
    emit("table1.tiny.ratio_vs_dense", f"{dense_bytes/comp_bytes:.2f}", "")


def _model_stream_stats(cfg, rng, sample_weights: int = 40_000_000):
    """Quantize synthetic trained-like weights tensor-by-tensor, build one
    model-wide dictionary from a sample, then measure hit rates on the rest.
    Memory stays bounded (per-tensor streaming, as the paper's per-layer
    files do)."""
    qcfg = QuantConfig(bits=8, granularity="per_channel")
    d, ff, v, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    hd = cfg.resolved_head_dim
    shapes = []
    for _ in range(L):
        shapes += [(cfg.n_heads * hd, d), (cfg.n_kv_heads * hd, d),
                   (cfg.n_kv_heads * hd, d), (d, cfg.n_heads * hd),
                   (ff, d), (ff, d), (d, ff)]
    shapes.append((v, d))

    total_weights = sum(a * b for a, b in shapes)
    budget = sample_weights
    streams = []
    for shape in shapes:
        n = shape[0] * shape[1]
        if budget <= 0:
            break
        take = min(n, budget)
        rows = max(1, take // shape[1])
        w = synthetic_trained_weights(rng, (rows, shape[1]))
        qt = quantize(jnp.asarray(w), qcfg)
        streams.append(np.asarray(qt.values, dtype=np.uint8).reshape(-1))
        budget -= rows * shape[1]

    sampled = np.concatenate(streams)
    table = codec.find_frequent_sequences([sampled], max_codes=65535)
    # hit rate on a held-out tensor
    w_test = synthetic_trained_weights(rng, (4096, d))
    qt = quantize(jnp.asarray(w_test), qcfg)
    stream = codec.compress_array(np.asarray(qt.values, np.uint8), table)
    n_esc = int((stream == codec.ESCAPE).sum())
    grams = w_test.size // 4
    hit = 1.0 - n_esc / grams
    # bytes/weight in the escape-stream format:
    # hit gram: 2 B per 4 weights; miss: 2 + 8 B per 4 weights
    bpw = (hit * 2 + (1 - hit) * 10) / 4
    table_bytes = codec.table_nbytes(table)
    comp_bytes = total_weights * bpw + table_bytes
    return {
        "total_weights": total_weights,
        "hit_rate": hit,
        "bytes_per_weight": bpw,
        "dense_mb": total_weights * 2 / 2**20,    # paper baseline is fp16
        "quant_mb": total_weights / 2**20,
        "comp_mb": comp_bytes / 2**20,
    }


def paper_scale_table():
    rng = np.random.default_rng(0)
    for arch in ("llama3.2-1b", "llama3.2-3b"):
        cfg = get_config(arch).full
        s = _model_stream_stats(cfg, rng)
        tag = arch.replace("llama3.2-", "")
        emit(f"table1.{tag}.dense_mb", f"{s['dense_mb']:.0f}",
             "fp16 baseline (paper: 2858/6584)")
        emit(f"table1.{tag}.quant_mb", f"{s['quant_mb']:.0f}",
             "int8 (paper: 1469/3522)")
        emit(f"table1.{tag}.compressed_mb", f"{s['comp_mb']:.0f}",
             f"escape stream, hit={s['hit_rate']:.3f} "
             f"({s['bytes_per_weight']:.3f} B/w) on synthetic trained-like "
             "weights")
        emit(f"table1.{tag}.ratio_vs_dense",
             f"{s['dense_mb']/s['comp_mb']:.1f}",
             "paper: 22.8x / 35.0x on real checkpoints")


def paper_verbatim_table():
    """Reproduce Table 1 via the paper's *verbatim* Listing 1+3 pipeline.

    Listing 1 stores DEQUANTIZED FLOATS back into ``param.data``; Listing 3
    then does ``.astype(np.uint8)`` — truncating every |w|<1 float to 0.
    The byte stream is therefore ~100% zeros: one dictionary entry, every
    gram hits, giving the format floor of 2 B per ``seq_len`` weights.
    This is (a) maximally compressible and (b) LOSSY — the decompressed
    bytes reconstruct the truncated stream, not the quantized weights.
    See EXPERIMENTS.md §Fidelity for the full analysis.
    """
    rng = np.random.default_rng(0)
    w = rng.laplace(0.0, 0.02, size=(1 << 20,)).astype(np.float32)
    mn, mx = w.min(), w.max()
    scale = (mx - mn) / 255.0
    zero = np.round(-mn / scale)
    q = np.clip(np.round(w / scale) + zero, 0, 255)
    deq = (scale * (q - zero)).astype(np.float32)
    stream = deq.astype(np.uint8)               # paper Listing 3, line 1
    frac_zero = float((stream == 0).mean())
    table = codec.find_frequent_sequences([stream])
    enc = codec.compress_array(stream, table)
    bpw = enc.nbytes / stream.size
    emit("table1.verbatim.zero_fraction", f"{frac_zero:.4f}",
         "float->uint8 truncation zeroes the stream (lossy)")
    emit("table1.verbatim.bytes_per_weight", f"{bpw:.4f}",
         "format floor = 2/seq_len = 0.5 B/w at seq_len=4")
    emit("table1.verbatim.ratio_vs_fp16", f"{2.0/bpw:.1f}",
         "paper reports 22.8x/35.0x; needs seq_len~23 at 100% hits — "
         "not reachable with the published seq_len=4 format")
    # losslessness check of the codec itself on this stream
    out = codec.decompress_array(enc, table, stream.size)
    emit("table1.verbatim.codec_lossless", int((out == stream).all()),
         "codec is exact over the (already-truncated) stream")


def main():
    tiny_scale_table()
    paper_scale_table()
    paper_verbatim_table()


if __name__ == "__main__":
    main()
