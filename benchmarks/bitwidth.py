"""Paper §3 ablation — ternary/2/4/6/8-bit naive quantization + GPTQ.

Reproduces the finding that drove Tiny-QMoE's design: ternary/2/4-bit
naive quantization destroys a small model (accuracy → chance, weight error
explodes) while 6/8-bit retains it, and GPTQ recovers part of the 4-bit
loss but still trails naive 8-bit.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.quant import QuantConfig, quantize, dequantize
from repro.core import gptq
from repro.models import lm as LM
from repro.train.steps import cross_entropy

from .common import emit, trained_tiny_model


def _quantize_model(params, bits, mode="naive", calib=None, cfg=None):
    def one(path, p):
        name = jax.tree_util.keystr(path)
        if p.ndim != 2 or p.size < 1024 or "norm" in name:
            return p
        if mode == "gptq":
            x = calib.reshape(-1, calib.shape[-1])
            if x.shape[-1] != p.shape[-1]:
                qc = QuantConfig(bits=bits, granularity="per_channel")
                return dequantize(quantize(p, qc))
            h = gptq.accumulate_hessian(gptq.init_hessian(p.shape[1]), x)
            return dequantize(gptq.gptq_quantize(
                p, h, QuantConfig(bits=bits)))
        qc = QuantConfig(bits=bits, granularity="per_tensor")  # paper-naive
        return dequantize(quantize(p, qc))

    return jax.tree_util.tree_map_with_path(one, params)


def main():
    cfg, params, data = trained_tiny_model(steps=150)
    batch = data.batch_at(9999)

    @jax.jit
    def loss_of(p):
        logits, _, _ = LM.forward(p, cfg, batch["tokens"])
        return cross_entropy(logits, batch["labels"])

    base = float(loss_of(params))
    emit("bitwidth.fp32.loss", f"{base:.4f}", "trained smoke model")

    for bits in (1.5, 2, 4, 6, 8):
        qp = _quantize_model(params, bits, mode="naive")
        l = float(loss_of(qp))
        tag = "ternary" if bits == 1.5 else f"{int(bits)}bit"
        emit(f"bitwidth.naive.{tag}.loss", f"{l:.4f}",
             f"delta={l-base:+.3f} (paper: <=4bit destroys, 8bit fine)")

    # GPTQ on the attention/FFN inputs (calibration = real activations ~ embeds)
    calib = jax.random.normal(jax.random.PRNGKey(0),
                              (512, cfg.d_model)) * 0.5
    for bits in (4, 8):
        qp = _quantize_model(params, bits, mode="gptq", calib=calib, cfg=cfg)
        l = float(loss_of(qp))
        emit(f"bitwidth.gptq.{int(bits)}bit.loss", f"{l:.4f}",
             f"delta={l-base:+.3f} (paper: GPTQ-4bit helps, still < 8bit)")


if __name__ == "__main__":
    main()
