"""Shared benchmark helpers: model building, timing, CSV emission."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm as LM
from repro.train.data import DataConfig, DataPipeline
from repro.train.optimizer import AdamWConfig
from repro.train.steps import TrainConfig, make_train_step, init_train_state


def emit(name: str, value, derived: str = "") -> None:
    """name,value,derived CSV row (the harness contract)."""
    print(f"{name},{value},{derived}", flush=True)


def time_call(fn, *args, warmup: int = 1, iters: int = 5):
    """Median wall time of ``fn(*args)`` with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def trained_tiny_model(arch_id: str = "llama3.2-1b", steps: int = 60,
                       seed: int = 0):
    """A briefly-trained smoke model — weights with *real* learned structure
    (random-init weights are incompressible; the paper compresses trained
    checkpoints)."""
    cfg = get_config(arch_id).smoke
    params = LM.init_lm(jax.random.PRNGKey(seed), cfg, jnp.float32)
    data = DataPipeline(DataConfig(vocab_size=cfg.vocab_size, batch=16,
                                   seq_len=32, seed=seed))
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-2, warmup_steps=10,
                                             total_steps=max(steps, 20)))
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    for i in range(steps):
        state, _ = step(state, data.batch_at(i))
    return cfg, state["params"], data


def synthetic_trained_weights(rng, shape, kurtotic: bool = True):
    """Weight tensor with trained-LLM-like statistics: heavy-tailed
    (Laplace-ish) per-row distributions.  Per-channel int8 quantization of
    such rows concentrates codes near the zero-point, which is what makes
    the paper's dictionary effective on real checkpoints."""
    if kurtotic:
        w = rng.laplace(0.0, 0.02, size=shape).astype(np.float32)
    else:
        w = rng.normal(0.0, 0.02, size=shape).astype(np.float32)
    return w
